//! Block-pipelined streaming executor — throughput serving for the
//! simulated cluster.
//!
//! [`super::run_distributed`] executes one inference at a time: every node
//! thread walks all plan blocks, and any device not hosting the active
//! block's current layer sits idle until the batch completes. That is the
//! right shape for the paper's metric (end-to-end latency of *one*
//! inference) but wastes the cluster under load: DEFER-style pipelining
//! (Parthasarathy & Krishnamachari, 2022) keeps every block busy by letting
//! consecutive inferences occupy different blocks concurrently, so
//! steady-state throughput is set by the *bottleneck* stage, not the sum of
//! stages.
//!
//! [`BlockPipeline`] reorganizes the exact same computation into one
//! persistent thread per fused block, connected by bounded channels:
//!
//! * **Stage 0** receives raw inputs, performs the scatter (leader slices
//!   the input into each node's entry requirement) and computes block 0.
//! * **Stage `b`** receives the per-node patch stores at block `b`'s entry
//!   boundary, computes the block's layers tile by tile, then performs the
//!   realignment exchange into block `b+1`'s entry requirement — byte for
//!   byte the messages the node threads' exchange protocol would send.
//! * **The final stage** gathers the last layer's tiles to the leader and
//!   emits a [`Completion`].
//!
//! Bounded channels give backpressure: up to `depth` submissions queue at
//! the entry and each stage holds one resident item, so `#blocks + depth`
//! inferences are in flight at most, each occupying a different block.
//! Completions leave in submission order (channels are FIFO and every stage
//! is serial), which [`BlockPipeline::wait_complete`] asserts.
//!
//! A pipeline generation is bound to one leader — the scatter/gather owner,
//! logical node 0, whose original rank
//! ([`crate::cluster::election::elect_leader`] over the liveness mask)
//! rides on [`BlockPipeline::start_with_leader`]. Losing a *worker* is a
//! normal drain ([`BlockPipeline::finish`]: in-flight inferences complete
//! under the old plan); losing the *leader* is an [`BlockPipeline::abort`]
//! (in-flight completions are discarded — the gather owner holding them is
//! gone — and the serving layer fails those requests explicitly before
//! rebuilding on the surviving node set).
//!
//! ## Why the numerics are bit-identical to lockstep
//!
//! A stage computes each node's tiles through the same
//! [`compute_tile_set`] dispatch, from patch stores holding the same patch
//! *set*, as the node threads do. Every output element has exactly one
//! accumulation order (fixed by its region and the kernel loop structure —
//! independent of blocking, of which worker computes the tile, and of
//! whether the input was extracted or read in place), so redundantly
//! computed overlaps carry equal values and patch order cannot change an
//! extract. The streaming entry point ([`crate::engine::execute_stream`])
//! asserts equality against the lockstep executor across the model zoo.
//!
//! Per-stage wall-clock busy time rides back on [`PipelineStats`]; the
//! *virtual-clock* stage times (what the planner's
//! [`crate::cost::Objective::Throughput`] minimizes) come from
//! [`crate::planner::exhaustive::stage_costs`], which attributes each
//! boundary transfer to the consuming stage (asynchronous sends) — the
//! host-side busy counters here attribute patch *assembly* to the
//! producing thread, so measured and predicted bottleneck stages can
//! differ by one; see `stage_costs` for the trade-off.
//!
//! The scatter/exchange/gather helpers below run the lockstep node
//! threads' protocol: the realignment message list comes from the shared
//! [`super::boundary_sends`] rule (one message per non-empty rect, same
//! byte pricing), so the two paths agree *by construction* — and the
//! executor tests still assert the outputs and the bytes/messages
//! accounting stay exactly equal, so a protocol change that misses one
//! side fails fast.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compute::{
    compute_tile_set, ComputeConfig, PatchStore, RegionTensor, Tensor, TensorArena, WeightStore,
};
use crate::model::Model;
use crate::partition::geometry::out_tiles;
use crate::partition::inflate::BlockGeometry;
use crate::partition::{Plan, Region, Scheme};
use crate::trace::{FlightRecorder, SpanRecord, KIND_STAGE};
use crate::DTYPE_BYTES;

/// One finished inference leaving the pipeline.
#[derive(Debug)]
pub struct Completion {
    /// Submission sequence number (0-based; completions arrive in order).
    pub seq: u64,
    pub output: Tensor,
    /// Payload bytes this inference moved across all boundaries (scatter,
    /// realignments, gather) — identical to the lockstep executor's
    /// accounting for the same plan.
    pub bytes_exchanged: u64,
    /// Inter-node messages this inference required.
    pub messages: usize,
}

/// Per-stage counters, returned when the pipeline drains.
#[derive(Debug, Clone)]
pub struct StageStats {
    /// Plan block index this stage executed.
    pub block: usize,
    /// Inclusive layer range of the block.
    pub layers: (usize, usize),
    /// Items processed.
    pub items: u64,
    /// Wall-clock time spent actively processing (scatter + compute +
    /// boundary assembly), excluding waits on either channel.
    pub busy: Duration,
    /// Payload bytes this stage sent downstream (stage 0 also counts the
    /// scatter; the final stage counts the gather).
    pub bytes_sent: u64,
    pub msgs_sent: usize,
    /// Tensor-buffer requests this stage's [`TensorArena`] served by
    /// recycling a previously freed buffer. The arena persists across
    /// items, so steady-state batches should be almost entirely reuses.
    pub buf_reuses: u64,
    /// Tensor-buffer requests that had to provision a fresh buffer.
    pub buf_allocs: u64,
}

/// Whole-pipeline statistics from [`BlockPipeline::finish`] or
/// [`BlockPipeline::abort`].
#[derive(Debug, Clone)]
pub struct PipelineStats {
    pub stages: Vec<StageStats>,
    /// Inferences whose completions were *delivered* to the pipeline's
    /// consumer — on an abort, in-flight completions are discarded and do
    /// not count.
    pub items: u64,
    /// Wall time from pipeline start to drain.
    pub elapsed: Duration,
    pub depth: usize,
    pub nodes: usize,
    /// Original rank of the node acting as leader (scatter/gather owner)
    /// for this pipeline generation — logical node 0 after
    /// [`crate::net::Testbed::subset`] compaction.
    pub leader: usize,
}

impl PipelineStats {
    /// Busy fraction per stage over the pipeline's lifetime (0..=1).
    pub fn occupancy(&self) -> Vec<f64> {
        let total = self.elapsed.as_secs_f64().max(1e-12);
        self.stages.iter().map(|s| s.busy.as_secs_f64() / total).collect()
    }

    /// Index of the busiest stage — the pipeline's measured bottleneck.
    pub fn bottleneck_stage(&self) -> usize {
        self.stages
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.busy.cmp(&b.1.busy))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// What flows between stages.
enum Payload {
    /// The raw model input — enters stage 0, which performs the scatter.
    Input(Tensor),
    /// Per-node patch stores at a block's entry boundary.
    Stores(Vec<PatchStore>),
}

struct Item {
    seq: u64,
    /// Trace id riding with this item (0 = untraced): stage threads record
    /// their busy interval for it when the pipeline holds a recorder.
    trace: u64,
    payload: Payload,
    /// Bytes/messages accumulated by the boundaries this item has crossed.
    bytes: u64,
    msgs: usize,
}

/// Immutable per-pipeline state shared by every stage thread.
struct StageCtx {
    model: Model,
    weights: WeightStore,
    blocks: Vec<(usize, usize, Scheme)>,
    geos: Vec<BlockGeometry>,
    nodes: usize,
    compute: ComputeConfig,
    /// Span sink for traced items (`None` = tracing off, zero overhead).
    recorder: Option<Arc<FlightRecorder>>,
}

enum StageOut {
    Stage(SyncSender<Item>),
    Done(Sender<Completion>),
}

/// The streaming executor: one thread per plan block, bounded channels in
/// between, completions in submission order.
pub struct BlockPipeline {
    input: Option<SyncSender<Item>>,
    done_rx: Receiver<Completion>,
    handles: Vec<std::thread::JoinHandle<StageStats>>,
    started: Instant,
    submitted: u64,
    completed: u64,
    nodes: usize,
    depth: usize,
    leader: usize,
}

impl BlockPipeline {
    /// Start the stage threads for `plan` on an `nodes`-device cluster with
    /// the baseline leader (original rank 0). `depth` bounds how many
    /// submissions may queue at the entry before [`Self::submit`] blocks
    /// (each stage additionally holds one resident item).
    pub fn start(
        model: &Model,
        plan: &Plan,
        weights: &WeightStore,
        nodes: usize,
        depth: usize,
    ) -> BlockPipeline {
        Self::start_with_leader(model, plan, weights, nodes, depth, 0)
    }

    /// [`Self::start`] with an explicit leader identity: `leader` is the
    /// *original* rank of the node acting as scatter/gather owner for this
    /// generation (after a failover, the lowest-ranked survivor). Execution
    /// addresses the leader as logical node 0 — the identity is carried for
    /// observability and for the serving layer's leader-loss accounting.
    pub fn start_with_leader(
        model: &Model,
        plan: &Plan,
        weights: &WeightStore,
        nodes: usize,
        depth: usize,
        leader: usize,
    ) -> BlockPipeline {
        Self::start_with(model, plan, weights, nodes, depth, leader, ComputeConfig::default())
    }

    /// [`Self::start_with_leader`] with explicit compute tuning — the
    /// serving router passes [`crate::serve::ServeConfig::compute`] here so
    /// every stage sizes its tile worker pool and buffer arena from one
    /// config.
    pub fn start_with(
        model: &Model,
        plan: &Plan,
        weights: &WeightStore,
        nodes: usize,
        depth: usize,
        leader: usize,
        compute: ComputeConfig,
    ) -> BlockPipeline {
        Self::start_traced(model, plan, weights, nodes, depth, leader, compute, None)
    }

    /// [`Self::start_with`] plus a span sink: stage threads record one
    /// `KIND_STAGE` span per traced item (`node` = stage index) into
    /// `recorder` — the serving router passes its flight recorder here so
    /// per-stage busy time joins each request's span tree.
    #[allow(clippy::too_many_arguments)]
    pub fn start_traced(
        model: &Model,
        plan: &Plan,
        weights: &WeightStore,
        nodes: usize,
        depth: usize,
        leader: usize,
        compute: ComputeConfig,
        recorder: Option<Arc<FlightRecorder>>,
    ) -> BlockPipeline {
        assert!(depth >= 1, "pipeline depth must be >= 1");
        let (blocks, geos) = super::plan_geometry(model, plan, nodes);
        let ctx = Arc::new(StageCtx {
            model: model.clone(),
            weights: weights.clone(),
            blocks,
            geos,
            nodes,
            compute,
            recorder,
        });
        let n_stages = ctx.blocks.len();
        let (done_tx, done_rx) = channel::<Completion>();

        // Build stages back to front so each thread owns its successor's
        // sender; the last `downstream` left over is the pipeline entry.
        let mut handles = Vec::with_capacity(n_stages);
        let mut downstream = StageOut::Done(done_tx);
        for bi in (0..n_stages).rev() {
            let cap = if bi == 0 { depth } else { 1 };
            let (tx, rx) = sync_channel::<Item>(cap);
            let ctx2 = Arc::clone(&ctx);
            let out = std::mem::replace(&mut downstream, StageOut::Stage(tx));
            handles.push(std::thread::spawn(move || stage_main(&ctx2, bi, rx, out)));
        }
        handles.reverse();
        let input = match downstream {
            StageOut::Stage(tx) => tx,
            StageOut::Done(_) => unreachable!("plans have at least one block"),
        };
        BlockPipeline {
            input: Some(input),
            done_rx,
            handles,
            started: Instant::now(),
            submitted: 0,
            completed: 0,
            nodes,
            depth,
            leader,
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Original rank of this generation's leader (scatter/gather owner).
    pub fn leader(&self) -> usize {
        self.leader
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Submissions not yet collected as completions.
    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed
    }

    /// Submit one inference; blocks when `depth` submissions are already
    /// queued at the entry (backpressure).
    pub fn submit(&mut self, input: Tensor) {
        self.submit_traced(input, 0);
    }

    /// [`Self::submit`] carrying a trace id (0 = untraced): each stage
    /// records its busy interval for this item when the pipeline was
    /// started with a recorder.
    pub fn submit_traced(&mut self, input: Tensor, trace: u64) {
        let seq = self.submitted;
        self.submitted += 1;
        self.input
            .as_ref()
            .expect("pipeline already drained")
            .send(Item { seq, trace, payload: Payload::Input(input), bytes: 0, msgs: 0 })
            .expect("pipeline stage died");
    }

    /// The next completion if one is ready (non-blocking). Completions
    /// arrive strictly in submission order.
    pub fn try_complete(&mut self) -> Option<Completion> {
        match self.done_rx.try_recv() {
            Ok(c) => {
                self.check_order(&c);
                Some(c)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                assert_eq!(
                    self.completed, self.submitted,
                    "pipeline stage died with work in flight"
                );
                None
            }
        }
    }

    /// Block for the next completion; `None` once every submission has
    /// completed.
    pub fn wait_complete(&mut self) -> Option<Completion> {
        if self.completed == self.submitted {
            return None;
        }
        let c = self
            .done_rx
            .recv()
            .expect("pipeline stage died with work in flight");
        self.check_order(&c);
        Some(c)
    }

    fn check_order(&mut self, c: &Completion) {
        assert_eq!(c.seq, self.completed, "pipeline completed out of order");
        self.completed += 1;
    }

    /// Drain the pipeline: close the entry, collect any outstanding
    /// completions, join the stage threads and return their statistics.
    pub fn finish(mut self) -> (Vec<Completion>, PipelineStats) {
        drop(self.input.take());
        let mut rest = Vec::new();
        while let Some(c) = self.wait_complete() {
            rest.push(c);
        }
        let stats = self.collect_stats(self.completed);
        (rest, stats)
    }

    /// Abort the generation after its leader died: close the entry, drain
    /// and *discard* the in-flight completions (their outputs lived on the
    /// dead gather owner and must not be delivered), join the stage threads
    /// and return `(aborted_in_flight, stats)`. `stats.items` counts only
    /// the completions delivered before the abort.
    pub fn abort(mut self) -> (u64, PipelineStats) {
        let delivered = self.completed;
        drop(self.input.take());
        while self.wait_complete().is_some() {}
        let stats = self.collect_stats(delivered);
        (self.submitted - delivered, stats)
    }

    fn collect_stats(&mut self, delivered: u64) -> PipelineStats {
        let mut stages = Vec::with_capacity(self.handles.len());
        for h in self.handles.drain(..) {
            stages.push(h.join().expect("pipeline stage panicked"));
        }
        PipelineStats {
            stages,
            items: delivered,
            elapsed: self.started.elapsed(),
            depth: self.depth,
            nodes: self.nodes,
            leader: self.leader,
        }
    }
}

/// Run `inputs` through a freshly started pipeline and collect all outputs
/// in submission order — the streaming counterpart of calling
/// [`super::run_distributed`] once per input.
pub fn run_pipelined(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    inputs: &[Tensor],
    nodes: usize,
    depth: usize,
) -> (Vec<Completion>, PipelineStats) {
    run_pipelined_cfg(model, plan, weights, inputs, nodes, depth, ComputeConfig::default())
}

/// [`run_pipelined`] with explicit compute tuning.
pub fn run_pipelined_cfg(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    inputs: &[Tensor],
    nodes: usize,
    depth: usize,
    compute: ComputeConfig,
) -> (Vec<Completion>, PipelineStats) {
    let mut pipe = BlockPipeline::start_with(model, plan, weights, nodes, depth, 0, compute);
    let mut out = Vec::with_capacity(inputs.len());
    for input in inputs {
        pipe.submit(input.clone());
        // reap opportunistically so the done queue never grows unboundedly
        while let Some(c) = pipe.try_complete() {
            out.push(c);
        }
    }
    let (rest, stats) = pipe.finish();
    out.extend(rest);
    (out, stats)
}

fn stage_main(ctx: &StageCtx, bi: usize, rx: Receiver<Item>, out: StageOut) -> StageStats {
    let (s, e, _) = ctx.blocks[bi];
    let mut stats = StageStats {
        block: bi,
        layers: (s, e),
        items: 0,
        busy: Duration::ZERO,
        bytes_sent: 0,
        msgs_sent: 0,
        buf_reuses: 0,
        buf_allocs: 0,
    };
    // The arena outlives the item loop, so buffers recycle *across* items:
    // after the first item warms the free list, steady-state batches run
    // allocation-free on this stage.
    let mut arena = TensorArena::new(ctx.compute.reuse_buffers);
    let mut items: Vec<(usize, Region)> = Vec::new();
    while let Ok(mut item) = rx.recv() {
        let t0 = Instant::now();
        let mut stores = match item.payload {
            Payload::Input(input) => {
                let (stores, b, m) = scatter(ctx, input, &mut arena);
                item.bytes += b;
                item.msgs += m;
                stats.bytes_sent += b;
                stats.msgs_sent += m;
                stores
            }
            Payload::Stores(stores) => stores,
        };

        // Block compute: every node's (possibly NT-inflated) tiles, layer
        // by layer — the whole layer's tile set (all nodes) fans out over
        // ctx.compute.tile_workers and merges back in (node, tile) order,
        // so each node's store receives its patches in the same order the
        // lockstep node threads produce them.
        let geo = &ctx.geos[bi];
        for l in s..=e {
            let layer = &ctx.model.layers[l];
            items.clear();
            for (node, tile) in geo.tiles[l - s].iter().enumerate() {
                items.extend(tile.iter().map(|r| (node, *r)));
            }
            let outs = {
                let store_refs: Vec<&PatchStore> = stores.iter().collect();
                compute_tile_set(
                    layer,
                    &ctx.weights.layers[l],
                    &store_refs,
                    &items,
                    &ctx.compute,
                    &mut arena,
                )
            };
            let mut next: Vec<PatchStore> = (0..ctx.nodes).map(|_| PatchStore::new()).collect();
            for (&(node, _), o) in items.iter().zip(outs) {
                if o.region.is_empty() {
                    arena.give(o.t);
                } else {
                    next[node].add(o);
                }
            }
            for store in stores.iter_mut() {
                arena.give_store(store);
            }
            stores = next;
        }

        match &out {
            StageOut::Stage(tx) => {
                let (next_stores, b, m) = exchange(ctx, bi, stores, &mut arena);
                item.bytes += b;
                item.msgs += m;
                stats.bytes_sent += b;
                stats.msgs_sent += m;
                stats.items += 1;
                let busy = t0.elapsed();
                stats.busy += busy;
                record_stage_span(ctx, bi, item.trace, busy);
                let fwd = Item {
                    seq: item.seq,
                    trace: item.trace,
                    payload: Payload::Stores(next_stores),
                    bytes: item.bytes,
                    msgs: item.msgs,
                };
                if tx.send(fwd).is_err() {
                    break; // downstream stage died; stop cleanly
                }
            }
            StageOut::Done(tx) => {
                let (output, b, m) = gather(ctx, stores, &mut arena);
                stats.bytes_sent += b;
                stats.msgs_sent += m;
                stats.items += 1;
                let busy = t0.elapsed();
                stats.busy += busy;
                record_stage_span(ctx, bi, item.trace, busy);
                let done = Completion {
                    seq: item.seq,
                    output,
                    bytes_exchanged: item.bytes + b,
                    messages: item.msgs + m,
                };
                if tx.send(done).is_err() {
                    break; // pipeline handle dropped; nothing left to report to
                }
            }
        }
    }
    stats.buf_reuses = arena.reuses;
    stats.buf_allocs = arena.allocs;
    stats
}

/// Record this stage's busy interval for a traced item (`node` carries the
/// stage index — stage threads share one process, so their spans live on
/// the recorder's single clock).
fn record_stage_span(ctx: &StageCtx, bi: usize, trace: u64, busy: Duration) {
    let Some(rec) = ctx.recorder.as_deref() else {
        return;
    };
    if trace == 0 {
        return;
    }
    let dur_ns = busy.as_nanos() as u64;
    rec.record(SpanRecord {
        trace_id: trace,
        gen: 0,
        kind: KIND_STAGE,
        node: bi as u32,
        start_ns: rec.now_ns().saturating_sub(dur_ns),
        dur_ns,
    });
}

/// The leader slices the model input into every node's entry requirement for
/// block 0 — same patches and byte accounting as the lockstep scatter. Takes
/// the input by value: the leader's own store holds the submitted tensor
/// itself, and peer slices come out of the stage arena.
fn scatter(ctx: &StageCtx, input: Tensor, arena: &mut TensorArena) -> (Vec<PatchStore>, u64, usize) {
    let l0 = &ctx.model.layers[0];
    let full_in = Region::full(l0.in_h, l0.in_w, l0.in_c);
    let whole = RegionTensor::new(full_in, input);
    let entry_need = &ctx.geos[0].entry_need;
    let mut stores: Vec<PatchStore> = (0..ctx.nodes).map(|_| PatchStore::new()).collect();
    let mut bytes = 0u64;
    let mut msgs = 0usize;
    for (to, need) in entry_need.iter().enumerate().skip(1) {
        for r in need {
            let patch = whole.slice_with(&r.intersect(&full_in), arena);
            if patch.region.is_empty() {
                continue;
            }
            bytes += patch.t.numel() as u64 * DTYPE_BYTES;
            msgs += 1;
            stores[to].add(patch);
        }
    }
    // the leader keeps the whole input locally (free)
    stores[0].add(whole);
    (stores, bytes, msgs)
}

/// The realignment exchange out of block `bi`: every producer's canonical
/// tiles intersected with every consumer's entry requirement, priced one
/// message per non-empty rect — exactly the matrix the cost model charges.
fn exchange(
    ctx: &StageCtx,
    bi: usize,
    mut stores: Vec<PatchStore>,
    arena: &mut TensorArena,
) -> (Vec<PatchStore>, u64, usize) {
    let (_, e, scheme) = ctx.blocks[bi];
    let producer = &ctx.model.layers[e];
    let have = out_tiles(producer, scheme, ctx.nodes);
    let need = &ctx.geos[bi + 1].entry_need;
    let mut bytes = 0u64;
    let mut msgs = 0usize;
    let mut incoming: Vec<Vec<RegionTensor>> = (0..ctx.nodes).map(|_| Vec::new()).collect();
    for (from, store) in stores.iter().enumerate() {
        // the one shared send rule — identical message list, order, and
        // pricing to what a lockstep node thread would put on the wire
        for (to, ov) in super::boundary_sends(&have, need, from) {
            let mut dense = arena.take(0, 0, 0);
            store.extract_into(&ov, &ov, true, &mut dense);
            bytes += dense.numel() as u64 * DTYPE_BYTES;
            msgs += 1;
            incoming[to].push(RegionTensor::new(ov, dense));
        }
    }
    let mut next: Vec<PatchStore> = (0..ctx.nodes).map(|_| PatchStore::new()).collect();
    for (node, store) in stores.iter_mut().enumerate() {
        for p in store.patches.drain(..) {
            next[node].add(p);
        }
    }
    for (node, inc) in incoming.into_iter().enumerate() {
        for p in inc {
            next[node].add(p);
        }
    }
    (next, bytes, msgs)
}

/// Gather the last layer's tiles to the leader and materialize the output.
/// Peer patches move (not clone) into the gathered store, and the consumed
/// stores' buffers return to the stage arena once the output is extracted.
fn gather(
    ctx: &StageCtx,
    mut stores: Vec<PatchStore>,
    arena: &mut TensorArena,
) -> (Tensor, u64, usize) {
    let last = ctx.model.layers.last().expect("non-empty model");
    let mut bytes = 0u64;
    let mut msgs = 0usize;
    let mut gathered = std::mem::take(&mut stores[0]);
    for store in stores.iter_mut().skip(1) {
        for rt in store.patches.drain(..) {
            bytes += rt.t.numel() as u64 * DTYPE_BYTES;
            msgs += 1;
            gathered.add(rt);
        }
    }
    let full = Region::full(last.out_h, last.out_w, last.out_c);
    let mut out = arena.take(0, 0, 0);
    gathered.extract_into(&full, &full, true, &mut out);
    arena.give_store(&mut gathered);
    (out, bytes, msgs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::run_distributed;
    use crate::compute::run_reference;
    use crate::model::zoo;
    use crate::partition::{Mode, Scheme};

    fn inputs(model: &Model, n: usize, seed: u64) -> Vec<Tensor> {
        let l0 = &model.layers[0];
        (0..n)
            .map(|i| Tensor::random(l0.in_h, l0.in_w, l0.in_c, seed + i as u64))
            .collect()
    }

    #[test]
    fn pipelined_outputs_match_lockstep_bit_for_bit() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let ins = inputs(&model, 5, 300);
        for scheme in [Scheme::InH, Scheme::OutC] {
            let plan = Plan::uniform(scheme, model.n_layers());
            for nodes in [1usize, 3, 4] {
                let (outs, stats) = run_pipelined(&model, &plan, &ws, &ins, nodes, 2);
                assert_eq!(outs.len(), ins.len());
                assert_eq!(stats.items, ins.len() as u64);
                assert_eq!(stats.stages.len(), plan.blocks().len());
                for (i, (c, input)) in outs.iter().zip(&ins).enumerate() {
                    assert_eq!(c.seq, i as u64, "completions out of order");
                    let lockstep = run_distributed(&model, &plan, &ws, input, nodes);
                    assert_eq!(
                        lockstep.output.max_abs_diff(&c.output),
                        0.0,
                        "{scheme} {nodes} nodes item {i}"
                    );
                    assert_eq!(c.bytes_exchanged, lockstep.bytes_exchanged);
                    assert_eq!(c.messages, lockstep.messages);
                }
            }
        }
    }

    #[test]
    fn fused_and_mixed_plans_pipeline_correctly() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 7);
        let n = model.n_layers();
        let mut plan = Plan::uniform(Scheme::InH, n);
        plan.steps[0].mode = Mode::NT;
        plan.steps[1].mode = Mode::NT;
        plan.steps[2].mode = Mode::NT;
        plan.steps[4].scheme = Scheme::OutC;
        plan.steps[5].scheme = Scheme::Grid2d;
        plan.validate().unwrap();
        let ins = inputs(&model, 4, 500);
        let (outs, stats) = run_pipelined(&model, &plan, &ws, &ins, 4, 3);
        assert_eq!(stats.stages.len(), plan.blocks().len());
        for (c, input) in outs.iter().zip(&ins) {
            let reference = run_reference(&model, &ws, input);
            assert_eq!(reference.max_abs_diff(&c.output), 0.0);
        }
    }

    #[test]
    fn stage_stats_account_for_all_items_and_bytes() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 3);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let ins = inputs(&model, 6, 900);
        let (outs, stats) = run_pipelined(&model, &plan, &ws, &ins, 4, 4);
        for st in &stats.stages {
            assert_eq!(st.items, 6, "stage {} missed items", st.block);
            assert!(st.busy > Duration::ZERO);
        }
        let per_item = outs[0].bytes_exchanged;
        assert!(per_item > 0);
        assert!(outs.iter().all(|c| c.bytes_exchanged == per_item));
        let stage_bytes: u64 = stats.stages.iter().map(|s| s.bytes_sent).sum();
        assert_eq!(stage_bytes, per_item * 6, "stage byte accounting must cover every item");
        let occ = stats.occupancy();
        assert_eq!(occ.len(), stats.stages.len());
        assert!(occ.iter().all(|&o| (0.0..=1.0).contains(&o)));
        assert!(stats.bottleneck_stage() < stats.stages.len());
    }

    #[test]
    fn incremental_submit_and_reap() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 5);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let mut pipe = BlockPipeline::start(&model, &plan, &ws, 4, 2);
        let ins = inputs(&model, 3, 40);
        for t in &ins {
            pipe.submit(t.clone());
        }
        assert_eq!(pipe.submitted(), 3);
        let first = pipe.wait_complete().expect("one completion due");
        assert_eq!(first.seq, 0);
        assert_eq!(pipe.in_flight(), 2);
        let (rest, stats) = pipe.finish();
        assert_eq!(rest.len(), 2);
        assert_eq!(stats.items, 3);
        let reference = run_reference(&model, &ws, &ins[2]);
        assert_eq!(reference.max_abs_diff(&rest[1].output), 0.0);
    }

    #[test]
    fn empty_pipeline_drains_cleanly() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let pipe = BlockPipeline::start(&model, &plan, &ws, 4, 1);
        let (rest, stats) = pipe.finish();
        assert!(rest.is_empty());
        assert_eq!(stats.items, 0);
        assert_eq!(stats.stages.len(), plan.blocks().len());
    }

    #[test]
    fn depth_one_pipeline_streams_correctly() {
        // the drain-and-flush edge case the serving router hits with
        // pipeline_depth just past lockstep: depth = 1 still overlaps
        // stages, still completes in order, still matches lockstep exactly
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 13);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let ins = inputs(&model, 4, 700);
        let (outs, stats) = run_pipelined(&model, &plan, &ws, &ins, 4, 1);
        assert_eq!(outs.len(), 4);
        assert_eq!(stats.depth, 1);
        for (i, (c, input)) in outs.iter().zip(&ins).enumerate() {
            assert_eq!(c.seq, i as u64);
            let lockstep = run_distributed(&model, &plan, &ws, input, 4);
            assert_eq!(lockstep.output.max_abs_diff(&c.output), 0.0, "item {i}");
            assert_eq!(c.bytes_exchanged, lockstep.bytes_exchanged);
        }
    }

    #[test]
    fn flush_with_zero_in_flight_and_rebuild_with_different_block_count() {
        // a generation boundary that finds nothing in flight (the router's
        // needs_flush can fire before any submission) must drain cleanly and
        // rebuild onto a plan with a different stage count
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 7);
        let n = model.n_layers();
        let plan_a = Plan::uniform(Scheme::InH, n);
        let stages_a = plan_a.blocks().len();
        // plan B fuses the first four layers: strictly fewer blocks
        let mut plan_b = Plan::uniform(Scheme::InH, n);
        plan_b.steps[0].mode = Mode::NT;
        plan_b.steps[1].mode = Mode::NT;
        plan_b.steps[2].mode = Mode::NT;
        plan_b.validate().unwrap();
        let stages_b = plan_b.blocks().len();
        assert_ne!(stages_a, stages_b, "plans must differ in block count");

        // generation 1: empty flush
        let gen1 = BlockPipeline::start(&model, &plan_a, &ws, 4, 2);
        let (rest, s1) = gen1.finish();
        assert!(rest.is_empty());
        assert_eq!((s1.items, s1.stages.len()), (0, stages_a));

        // generation 2: rebuild on plan B, serve, drain with work in flight
        let ins = inputs(&model, 3, 810);
        let mut gen2 = BlockPipeline::start(&model, &plan_b, &ws, 4, 2);
        for t in &ins {
            gen2.submit(t.clone());
        }
        let (rest, s2) = gen2.finish();
        assert_eq!(rest.len(), 3);
        assert_eq!(s2.stages.len(), stages_b);
        for (c, input) in rest.iter().zip(&ins) {
            let reference = run_reference(&model, &ws, input);
            assert_eq!(reference.max_abs_diff(&c.output), 0.0);
        }
    }

    #[test]
    fn traced_items_record_one_span_per_stage() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 9);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let n_stages = plan.blocks().len();
        let rec = Arc::new(FlightRecorder::new());
        let mut pipe = BlockPipeline::start_traced(
            &model,
            &plan,
            &ws,
            4,
            2,
            0,
            ComputeConfig::default(),
            Some(Arc::clone(&rec)),
        );
        let ins = inputs(&model, 4, 820);
        for (i, t) in ins.iter().enumerate() {
            pipe.submit_traced(t.clone(), 100 + i as u64);
        }
        let (rest, _) = pipe.finish();
        assert_eq!(rest.len(), 4);
        let spans = rec.snapshot();
        for i in 0..4u64 {
            let trace = 100 + i;
            let mine: Vec<_> =
                spans.iter().filter(|s| s.trace_id == trace && s.kind == KIND_STAGE).collect();
            assert_eq!(mine.len(), n_stages, "trace {trace} missing stage spans");
            let mut stages: Vec<u32> = mine.iter().map(|s| s.node).collect();
            stages.sort_unstable();
            assert_eq!(stages, (0..n_stages as u32).collect::<Vec<_>>());
            assert!(mine.iter().all(|s| s.dur_ns > 0), "stage spans must carry busy time");
        }
    }

    #[test]
    fn untraced_items_record_nothing() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 9);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let rec = Arc::new(FlightRecorder::new());
        let mut pipe = BlockPipeline::start_traced(
            &model,
            &plan,
            &ws,
            3,
            1,
            0,
            ComputeConfig::default(),
            Some(Arc::clone(&rec)),
        );
        pipe.submit(inputs(&model, 1, 830).pop().unwrap());
        let (rest, _) = pipe.finish();
        assert_eq!(rest.len(), 1);
        assert_eq!(rec.recorded(), 0, "trace id 0 must not record spans");
    }

    #[test]
    fn abort_discards_in_flight_and_accounts_for_them() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 5);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let mut pipe = BlockPipeline::start_with_leader(&model, &plan, &ws, 4, 4, 0);
        assert_eq!(pipe.leader(), 0);
        let ins = inputs(&model, 3, 60);
        for t in &ins {
            pipe.submit(t.clone());
        }
        // deliver exactly one completion, then abort with two in flight
        let first = pipe.wait_complete().expect("one completion due");
        assert_eq!(first.seq, 0);
        let (aborted, stats) = pipe.abort();
        assert_eq!(aborted, 2, "in-flight completions must be counted, not delivered");
        assert_eq!(stats.items, 1, "only the delivered completion counts");
        assert_eq!(stats.leader, 0);
    }

    #[test]
    fn abort_with_nothing_in_flight_is_a_clean_drain() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 5);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let pipe = BlockPipeline::start_with_leader(&model, &plan, &ws, 3, 1, 2);
        let (aborted, stats) = pipe.abort();
        assert_eq!(aborted, 0);
        assert_eq!(stats.items, 0);
        assert_eq!(stats.leader, 2, "leader identity must ride on the stats");
    }
}
