//! Simulated edge cluster — the TMS320C6678-testbed substitute.
//!
//! `N` worker threads stand in for the `N` edge devices. The leader
//! (logical node 0 — under failure, the lowest-ranked survivor elected by
//! [`election::elect_leader`]) holds the model input, scatters each node's
//! entry requirement, and gathers the final output; between blocks, nodes
//! exchange *real tensor halos* over channels according to the exact
//! message matrices the cost model prices. Every node derives the plan
//! geometry independently (as the paper's devices do from the deployed
//! partition scheme), so the exchange protocol is deterministic: each node
//! knows precisely how many patches to expect at every boundary.
//!
//! Wall-clock timing of these threads is *not* the reported inference time —
//! the host is one shared CPU, not four DSPs. Reported times come from the
//! virtual clock (the analytic cost model) via [`crate::engine::evaluate`];
//! this module is what makes the *numerics* of a plan real and checkable.
//!
//! [`run_distributed`] executes one inference in lockstep. For throughput
//! serving, [`pipeline`] reorganizes the same computation into per-block
//! stage threads so consecutive inferences overlap across plan blocks.

pub mod election;
pub mod pipeline;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::compute::{compute_region, PatchStore, RegionTensor, Tensor, WeightStore};
use crate::model::Model;
use crate::partition::geometry::out_tiles;
use crate::partition::inflate::BlockGeometry;
use crate::partition::{Plan, Region, Tile};

/// A halo/boundary message: a tensor patch for a given boundary index.
struct Msg {
    boundary: usize,
    patch: RegionTensor,
}

/// Per-boundary traffic accounting: the payload and message count one
/// exchange boundary moved, summed over all nodes. Indexed like the
/// protocol's boundary counter (0 = scatter, `b + 1` = the exchange after
/// block `b`, the last entry = gather). This is the observable the
/// telemetry probes measure ([`crate::telemetry::probe`]): bytes over
/// elapsed wire time is an effective-bandwidth sample, and the serving
/// router feeds each batch's totals back through
/// [`crate::elastic::ConditionSource::observe_traffic`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BoundaryTraffic {
    pub bytes: u64,
    pub msgs: u64,
}

/// Result of a distributed run.
#[derive(Debug)]
pub struct ClusterRun {
    pub output: Tensor,
    /// Total payload bytes moved between nodes (all boundaries).
    pub bytes_exchanged: u64,
    /// Number of inter-node messages.
    pub messages: usize,
    /// The same traffic broken down per exchange boundary — the
    /// measurement hook for per-link telemetry.
    pub boundary_traffic: Vec<BoundaryTraffic>,
}

/// Execute `plan` for `model` on `nodes` simulated devices with real
/// numerics. Returns the gathered output (identical to the single-node
/// reference up to f32 associativity — exactly equal here, since each output
/// element is computed by exactly one accumulation order).
pub fn run_distributed(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    nodes: usize,
) -> ClusterRun {
    plan.validate().expect("invalid plan");
    assert_eq!(plan.steps.len(), model.n_layers());
    let layers = &model.layers;
    let blocks = plan.blocks();
    let geos: Arc<Vec<BlockGeometry>> = Arc::new(
        blocks
            .iter()
            .map(|&(s, e, scheme)| BlockGeometry::new(&layers[s..=e], scheme, nodes))
            .collect(),
    );
    let blocks = Arc::new(blocks);
    let weights = Arc::new(weights.clone());
    let model = Arc::new(model.clone());
    let input = Arc::new(input.clone());

    // channels[to] — every node owns one receiver; all others share senders.
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(nodes);
    let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let (tx, rx) = channel::<Msg>();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    let mut handles = Vec::new();
    for node in 0..nodes {
        let rx = Mailbox::new(receivers[node].take().unwrap());
        let txs: Vec<Sender<Msg>> = senders.clone();
        let model = Arc::clone(&model);
        let weights = Arc::clone(&weights);
        let input = Arc::clone(&input);
        let geos = Arc::clone(&geos);
        let blocks = Arc::clone(&blocks);
        handles.push(std::thread::spawn(move || {
            node_main(node, nodes, &model, &blocks, &geos, &weights, &input, rx, &txs)
        }));
    }
    drop(senders);

    let mut output = None;
    let mut bytes = 0u64;
    let mut messages = 0usize;
    let mut boundary_traffic = vec![BoundaryTraffic::default(); geos.len() + 1];
    for (node, h) in handles.into_iter().enumerate() {
        let res = h.join().expect("node thread panicked");
        bytes += res.sent_bytes;
        messages += res.sent_msgs;
        for (sum, t) in boundary_traffic.iter_mut().zip(&res.traffic) {
            sum.bytes += t.bytes;
            sum.msgs += t.msgs;
        }
        if node == 0 {
            output = res.output;
        }
    }
    ClusterRun {
        output: output.expect("leader produced no output"),
        bytes_exchanged: bytes,
        messages,
        boundary_traffic,
    }
}

/// Execute `plan` on the surviving sub-cluster described by `alive` — the
/// failure-injection entry point used by [`crate::elastic`] when a device
/// drops out. Node identity only selects a tile index, so a failed device's
/// share of work redistributes by running the same deterministic protocol on
/// the smaller logical cluster (ids compact in original order, matching
/// [`crate::net::Testbed::subset`]). The compaction also implements leader
/// failover: the lowest-ranked survivor — exactly the node
/// [`election::elect_leader`] picks — lands at logical 0 and owns
/// scatter/gather, so a mask with `alive[0] == false` runs with the new
/// leader in place and no special casing. The plan itself is
/// node-count-agnostic (`Plan::validate` is structural), so any valid plan
/// executes — though an optimal swap-in plan should come from replanning on
/// the degraded testbed.
pub fn run_degraded(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    alive: &[bool],
) -> ClusterRun {
    let survivors = alive.iter().filter(|&&a| a).count();
    assert!(survivors >= 1, "no surviving nodes");
    run_distributed(model, plan, weights, input, survivors)
}

struct NodeResult {
    output: Option<Tensor>,
    sent_bytes: u64,
    sent_msgs: usize,
    /// This node's sent traffic per exchange boundary.
    traffic: Vec<BoundaryTraffic>,
}

/// How many patches `to` receives from all peers at `boundary`, given the
/// deterministic send rule (one patch per non-empty rect intersection).
fn expected_patches(have: &[Tile], need: &[Tile], to: usize) -> usize {
    let mut count = 0;
    for (from, h) in have.iter().enumerate() {
        if from == to {
            continue;
        }
        for ra in h {
            for rb in &need[to] {
                if !ra.intersect(rb).is_empty() {
                    count += 1;
                }
            }
        }
    }
    count
}

#[allow(clippy::too_many_arguments)]
fn node_main(
    node: usize,
    nodes: usize,
    model: &Model,
    blocks: &[(usize, usize, crate::partition::Scheme)],
    geos: &[BlockGeometry],
    weights: &WeightStore,
    input: &Tensor,
    rx: Mailbox,
    txs: &[Sender<Msg>],
) -> NodeResult {
    let layers = &model.layers;
    let n = layers.len();
    let mut sent_bytes = 0u64;
    let mut sent_msgs = 0usize;
    let mut traffic = vec![BoundaryTraffic::default(); blocks.len() + 1];
    let mut boundary = 0usize; // scatter = 0, after block b = b+1

    // --- scatter -----------------------------------------------------------
    let l0 = &layers[0];
    let full_in = Region::full(l0.in_h, l0.in_w, l0.in_c);
    let mut rx = rx;
    let mut store = PatchStore::new();
    {
        let entry_need = &geos[0].entry_need;
        if node == 0 {
            let whole = RegionTensor::new(full_in, input.clone());
            // keep own requirement locally
            store.add(whole.clone());
            for (to, need) in entry_need.iter().enumerate().skip(1) {
                for r in need {
                    let patch = whole.slice(&r.intersect(&full_in));
                    if patch.region.is_empty() {
                        continue;
                    }
                    sent_bytes += patch.t.numel() as u64 * 4;
                    sent_msgs += 1;
                    traffic[boundary].bytes += patch.t.numel() as u64 * 4;
                    traffic[boundary].msgs += 1;
                    txs[to].send(Msg { boundary, patch }).unwrap();
                }
            }
        } else {
            let expect: usize = entry_need[node]
                .iter()
                .filter(|r| !r.intersect(&full_in).is_empty())
                .count();
            rx.recv_for(boundary, expect, &mut store);
        }
    }
    boundary += 1;

    // --- blocks ------------------------------------------------------------
    for (bi, &(s, e, scheme)) in blocks.iter().enumerate() {
        let geo = &geos[bi];
        // compute layers s..=e on the (inflated) tiles
        for l in s..=e {
            let layer = &layers[l];
            let mut next = PatchStore::new();
            for r in &geo.tiles[l - s][node] {
                let out = compute_region(layer, &weights.layers[l], &store, r);
                next.add(out);
            }
            store = next;
        }
        // boundary out of this block
        let producer = &layers[e];
        let have = out_tiles(producer, scheme, nodes);
        if e == n - 1 {
            // gather to leader
            if node != 0 {
                for rt in &store.patches {
                    sent_bytes += rt.t.numel() as u64 * 4;
                    sent_msgs += 1;
                    traffic[boundary].bytes += rt.t.numel() as u64 * 4;
                    traffic[boundary].msgs += 1;
                    txs[0].send(Msg { boundary, patch: rt.clone() }).unwrap();
                }
            } else {
                let expect: usize = (1..nodes)
                    .map(|other| have[other].iter().filter(|r| !r.is_empty()).count())
                    .sum();
                let mut gathered = store;
                rx.recv_for(boundary, expect, &mut gathered);
                let last = &layers[n - 1];
                let full = Region::full(last.out_h, last.out_w, last.out_c);
                let out = gathered.extract(&full, &full, true);
                return NodeResult { output: Some(out), sent_bytes, sent_msgs, traffic };
            }
        } else {
            let need: Vec<Tile> = geos[bi + 1].entry_need.clone();
            // send: my canonical tiles ∩ everyone's needs
            for (to, nb) in need.iter().enumerate() {
                if to == node {
                    continue;
                }
                for ra in &have[node] {
                    for rb in nb {
                        let ov = ra.intersect(rb);
                        if ov.is_empty() {
                            continue;
                        }
                        // find the patch data (store holds this block's
                        // outputs, which cover the canonical tile)
                        let mut tmp = PatchStore::new();
                        let dense = store.extract(&ov, &ov, true);
                        tmp.add(RegionTensor::new(ov, dense));
                        let patch = tmp.patches.pop().unwrap();
                        sent_bytes += patch.t.numel() as u64 * 4;
                        sent_msgs += 1;
                        traffic[boundary].bytes += patch.t.numel() as u64 * 4;
                        traffic[boundary].msgs += 1;
                        txs[to].send(Msg { boundary, patch }).unwrap();
                    }
                }
            }
            // receive + keep own data
            let expect = expected_patches(&have, &need, node);
            let mut next = PatchStore::new();
            for p in store.patches.drain(..) {
                next.add(p);
            }
            rx.recv_for(boundary, expect, &mut next);
            store = next;
        }
        boundary += 1;
    }
    NodeResult { output: None, sent_bytes, sent_msgs, traffic }
}

/// Receiver with reordering: a fast peer may already be sending patches for
/// a *later* boundary while this node still waits on the current one, so
/// messages tagged ahead are buffered; messages tagged behind are protocol
/// violations.
struct Mailbox {
    rx: Receiver<Msg>,
    pending: Vec<Msg>,
}

impl Mailbox {
    fn new(rx: Receiver<Msg>) -> Mailbox {
        Mailbox { rx, pending: Vec::new() }
    }

    /// Receive exactly `expect` patches tagged `boundary` into `store`.
    fn recv_for(&mut self, boundary: usize, expect: usize, store: &mut PatchStore) {
        let mut got = 0usize;
        // drain previously buffered patches for this boundary
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].boundary == boundary {
                let msg = self.pending.swap_remove(i);
                store.add(msg.patch);
                got += 1;
            } else {
                i += 1;
            }
        }
        while got < expect {
            let msg = self.rx.recv().expect("peer disconnected");
            if msg.boundary == boundary {
                store.add(msg.patch);
                got += 1;
            } else {
                assert!(
                    msg.boundary > boundary,
                    "stale message for boundary {} while at {boundary}",
                    msg.boundary
                );
                self.pending.push(msg);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::run_reference;
    use crate::model::zoo;
    use crate::partition::{Mode, Scheme};

    fn check_plan(model: &Model, plan: &Plan, nodes: usize) {
        let ws = WeightStore::for_model(model, 11);
        let l0 = &model.layers[0];
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, 99);
        let reference = run_reference(model, &ws, &input);
        let run = run_distributed(model, plan, &ws, &input, nodes);
        let diff = reference.max_abs_diff(&run.output);
        assert_eq!(diff, 0.0, "distributed != reference (diff {diff})");
    }

    #[test]
    fn uniform_plans_match_reference() {
        let model = zoo::edgenet(16);
        for scheme in Scheme::ALL {
            for nodes in [2usize, 3, 4] {
                let plan = Plan::uniform(scheme, model.n_layers());
                check_plan(&model, &plan, nodes);
            }
        }
    }

    #[test]
    fn fused_plan_matches_reference() {
        let model = zoo::edgenet(16);
        let mut plan = Plan::uniform(Scheme::InH, model.n_layers());
        // fuse the first four layers (conv, dw, pw, conv)
        plan.steps[0].mode = Mode::NT;
        plan.steps[1].mode = Mode::NT;
        plan.steps[2].mode = Mode::NT;
        plan.validate().unwrap();
        check_plan(&model, &plan, 4);
    }

    #[test]
    fn mixed_scheme_plan_matches_reference() {
        let model = zoo::edgenet(16);
        let n = model.n_layers();
        let mut plan = Plan::uniform(Scheme::InH, n);
        plan.steps[2].scheme = Scheme::OutC;
        plan.steps[3].scheme = Scheme::Grid2d;
        plan.steps[4].scheme = Scheme::InW;
        plan.steps[6].scheme = Scheme::OutC;
        plan.validate().unwrap();
        check_plan(&model, &plan, 4);
    }

    #[test]
    fn grid_on_three_nodes_matches_reference() {
        // the imbalanced multi-rect tile case
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::Grid2d, model.n_layers());
        check_plan(&model, &plan, 3);
    }

    #[test]
    fn bytes_exchanged_positive_and_counted() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let input = Tensor::random(16, 16, 3, 2);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_distributed(&model, &plan, &ws, &input, 4);
        assert!(run.bytes_exchanged > 0);
        assert!(run.messages > 0);
    }

    #[test]
    fn boundary_traffic_decomposes_the_totals() {
        // the per-boundary measurement hook must tile the aggregate
        // accounting exactly: one entry per exchange boundary, summing to
        // the run totals, with scatter and gather both visibly non-empty
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let input = Tensor::random(16, 16, 3, 2);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_distributed(&model, &plan, &ws, &input, 4);
        assert_eq!(run.boundary_traffic.len(), plan.blocks().len() + 1);
        let bytes: u64 = run.boundary_traffic.iter().map(|t| t.bytes).sum();
        let msgs: u64 = run.boundary_traffic.iter().map(|t| t.msgs).sum();
        assert_eq!(bytes, run.bytes_exchanged, "boundary bytes don't tile the total");
        assert_eq!(msgs, run.messages as u64, "boundary messages don't tile the total");
        let scatter = run.boundary_traffic.first().unwrap();
        let gather = run.boundary_traffic.last().unwrap();
        assert!(scatter.bytes > 0, "scatter moved nothing");
        assert!(gather.bytes > 0, "gather moved nothing");
        // single-node runs move nothing at any boundary
        let solo = run_distributed(&model, &plan, &ws, &input, 1);
        assert!(solo.boundary_traffic.iter().all(|t| t.bytes == 0 && t.msgs == 0));
    }

    #[test]
    fn degraded_cluster_still_matches_reference() {
        // kill one of four nodes: the remaining three produce bit-identical
        // output (work redistributes; every element keeps one accumulation
        // order)
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let input = Tensor::random(16, 16, 3, 42);
        let reference = run_reference(&model, &ws, &input);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_degraded(&model, &plan, &ws, &input, &[true, true, false, true]);
        assert_eq!(reference.max_abs_diff(&run.output), 0.0);
    }

    #[test]
    fn dead_leader_cluster_still_matches_reference() {
        // kill node 0: the lowest-ranked survivor (original rank 1) compacts
        // to logical 0 and takes over scatter/gather — the numerics don't
        // change, because node identity only selects a tile index
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let input = Tensor::random(16, 16, 3, 42);
        let reference = run_reference(&model, &ws, &input);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let alive = [false, true, true, true];
        assert_eq!(super::election::elect_leader(&alive), Some(1));
        let run = run_degraded(&model, &plan, &ws, &input, &alive);
        assert_eq!(reference.max_abs_diff(&run.output), 0.0);
    }

    #[test]
    #[should_panic(expected = "no surviving nodes")]
    fn degraded_cluster_needs_a_survivor() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let input = Tensor::random(16, 16, 3, 1);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        run_degraded(&model, &plan, &ws, &input, &[false, false]);
    }

    #[test]
    fn single_node_degenerate_cluster() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let input = Tensor::random(16, 16, 3, 99);
        let reference = run_reference(&model, &ws, &input);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_distributed(&model, &plan, &ws, &input, 1);
        assert_eq!(reference.max_abs_diff(&run.output), 0.0);
        assert_eq!(run.bytes_exchanged, 0);
    }
}
