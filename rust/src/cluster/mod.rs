//! Simulated edge cluster — the TMS320C6678-testbed substitute.
//!
//! `N` worker threads stand in for the `N` edge devices. The leader
//! (logical node 0 — under failure, the lowest-ranked survivor elected by
//! [`election::elect_leader`]) holds the model input, scatters each node's
//! entry requirement, and gathers the final output; between blocks, nodes
//! exchange *real tensor halos* according to the exact message matrices the
//! cost model prices. Every node derives the plan geometry independently
//! (as the paper's devices do from the deployed partition scheme), so the
//! exchange protocol is deterministic: each node knows precisely how many
//! patches to expect at every boundary.
//!
//! The protocol itself ([`node_main`]) is generic over the
//! [`crate::transport::Exchange`] fabric: [`SimExchange`] runs it over
//! in-process mpsc channels (the deterministic test/CI mode used here),
//! and [`crate::transport::tcp::TcpExchange`] runs the byte-identical
//! protocol between real OS processes over TCP/UDS — the
//! [`crate::transport::daemon`] path. Either way, peer death surfaces
//! *mid-batch* as a typed [`crate::transport::TransportError`], not only
//! at batch boundaries.
//!
//! Wall-clock timing of these threads is *not* the reported inference time —
//! the host is one shared CPU, not four DSPs. Reported times come from the
//! virtual clock (the analytic cost model) via [`crate::engine::evaluate`];
//! this module is what makes the *numerics* of a plan real and checkable.
//!
//! [`run_distributed`] executes one inference in lockstep. For throughput
//! serving, [`pipeline`] reorganizes the same computation into per-block
//! stage threads so consecutive inferences overlap across plan blocks.

pub mod election;
pub mod pipeline;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compute::{
    compute_tile_set, ComputeConfig, PatchStore, RegionTensor, Tensor, TensorArena, WeightStore,
};
use crate::model::Model;
use crate::partition::geometry::out_tiles;
use crate::partition::inflate::BlockGeometry;
use crate::partition::{Plan, Region, Scheme, Tile};
use crate::transport::{Exchange, TransportError};
use crate::DTYPE_BYTES;

/// A halo/boundary message: a tensor patch for a given boundary index.
struct Msg {
    boundary: usize,
    patch: RegionTensor,
}

/// Per-boundary traffic accounting: the payload and message count one
/// exchange boundary moved, summed over all nodes. Indexed like the
/// protocol's boundary counter (0 = scatter, `b + 1` = the exchange after
/// block `b`, the last entry = gather). This is the observable the
/// telemetry probes measure ([`crate::telemetry::probe`]): bytes over
/// elapsed wire time is an effective-bandwidth sample, and the serving
/// router feeds each batch's totals back through
/// [`crate::elastic::ConditionSource::observe_traffic`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BoundaryTraffic {
    pub bytes: u64,
    pub msgs: u64,
}

/// Result of a distributed run.
#[derive(Debug)]
pub struct ClusterRun {
    pub output: Tensor,
    /// Total payload bytes moved between nodes (all boundaries).
    pub bytes_exchanged: u64,
    /// Number of inter-node messages.
    pub messages: usize,
    /// The same traffic broken down per exchange boundary — the
    /// measurement hook for per-link telemetry.
    pub boundary_traffic: Vec<BoundaryTraffic>,
}

/// Validate `plan` against `model` and derive the per-block geometry every
/// node computes independently. Shared by the in-process runner and the
/// process-mode daemon, so both fabrics execute identical tile math.
pub(crate) fn plan_geometry(
    model: &Model,
    plan: &Plan,
    nodes: usize,
) -> (Vec<(usize, usize, Scheme)>, Vec<BlockGeometry>) {
    plan.validate().expect("invalid plan");
    assert_eq!(plan.steps.len(), model.n_layers());
    let layers = &model.layers;
    let blocks = plan.blocks();
    let geos: Vec<BlockGeometry> = blocks
        .iter()
        .map(|&(s, e, scheme)| BlockGeometry::new(&layers[s..=e], scheme, nodes))
        .collect();
    (blocks, geos)
}

/// Execute `plan` for `model` on `nodes` simulated devices with real
/// numerics. Returns the gathered output (identical to the single-node
/// reference up to f32 associativity — exactly equal here, since each output
/// element is computed by exactly one accumulation order).
pub fn run_distributed(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    nodes: usize,
) -> ClusterRun {
    run_distributed_cfg(model, plan, weights, input, nodes, &ComputeConfig::default())
}

/// [`run_distributed`] with explicit compute tuning (worker pool size,
/// buffer-arena behavior) — the serving router passes
/// [`crate::serve::ServeConfig::compute`] through here.
pub fn run_distributed_cfg(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    nodes: usize,
    cfg: &ComputeConfig,
) -> ClusterRun {
    let cfg = *cfg;
    let (blocks, geos) = plan_geometry(model, plan, nodes);
    let geos = Arc::new(geos);
    let blocks = Arc::new(blocks);
    let weights = Arc::new(weights.clone());
    let model = Arc::new(model.clone());
    let input = Arc::new(input.clone());

    // channels[to] — every node owns one receiver; all others share senders.
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(nodes);
    let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let (tx, rx) = channel::<Msg>();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    let mut handles = Vec::new();
    for node in 0..nodes {
        let rx = receivers[node].take().unwrap();
        let txs: Vec<Sender<Msg>> = senders.clone();
        let model = Arc::clone(&model);
        let weights = Arc::clone(&weights);
        let input = if node == 0 { Some(Arc::clone(&input)) } else { None };
        let geos = Arc::clone(&geos);
        let blocks = Arc::clone(&blocks);
        handles.push(std::thread::spawn(move || {
            let mut ex = SimExchange::new(node, txs, rx);
            node_main(
                node,
                nodes,
                &model,
                &blocks,
                &geos,
                &weights,
                input.as_deref(),
                &mut ex,
                &cfg,
            )
        }));
    }
    drop(senders);

    let mut output = None;
    let mut bytes = 0u64;
    let mut messages = 0usize;
    let mut boundary_traffic = vec![BoundaryTraffic::default(); geos.len() + 1];
    for (node, h) in handles.into_iter().enumerate() {
        let res = h
            .join()
            .expect("node thread panicked")
            .unwrap_or_else(|e| panic!("node {node} transport failure: {e}"));
        bytes += res.sent_bytes;
        messages += res.sent_msgs;
        for (sum, t) in boundary_traffic.iter_mut().zip(&res.traffic) {
            sum.bytes += t.bytes;
            sum.msgs += t.msgs;
        }
        if node == 0 {
            output = res.output;
        }
    }
    ClusterRun {
        output: output.expect("leader produced no output"),
        bytes_exchanged: bytes,
        messages,
        boundary_traffic,
    }
}

/// Execute `plan` on the surviving sub-cluster described by `alive` — the
/// failure-injection entry point used by [`crate::elastic`] when a device
/// drops out. Node identity only selects a tile index, so a failed device's
/// share of work redistributes by running the same deterministic protocol on
/// the smaller logical cluster (ids compact in original order, matching
/// [`crate::net::Testbed::subset`]). The compaction also implements leader
/// failover: the lowest-ranked survivor — exactly the node
/// [`election::elect_leader`] picks — lands at logical 0 and owns
/// scatter/gather, so a mask with `alive[0] == false` runs with the new
/// leader in place and no special casing. The plan itself is
/// node-count-agnostic (`Plan::validate` is structural), so any valid plan
/// executes — though an optimal swap-in plan should come from replanning on
/// the degraded testbed.
pub fn run_degraded(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    alive: &[bool],
) -> ClusterRun {
    run_degraded_cfg(model, plan, weights, input, alive, &ComputeConfig::default())
}

/// [`run_degraded`] with explicit compute tuning.
pub fn run_degraded_cfg(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    alive: &[bool],
    cfg: &ComputeConfig,
) -> ClusterRun {
    let survivors = alive.iter().filter(|&&a| a).count();
    assert!(survivors >= 1, "no surviving nodes");
    run_distributed_cfg(model, plan, weights, input, survivors, cfg)
}

pub(crate) struct NodeResult {
    pub(crate) output: Option<Tensor>,
    pub(crate) sent_bytes: u64,
    pub(crate) sent_msgs: usize,
    /// This node's sent traffic per exchange boundary.
    pub(crate) traffic: Vec<BoundaryTraffic>,
}

/// The deterministic send rule at a block boundary: everything `from`'s
/// canonical tiles (`have[from]`) contribute to every peer's entry needs —
/// one patch per non-empty rect intersection, enumerated in `(to, have
/// rect, need rect)` order. Both execution paths (lockstep [`node_main`]
/// and the pipelined stage helpers) and both fabrics derive their message
/// lists from this one function, so byte/message accounting agrees
/// everywhere by construction.
pub(crate) fn boundary_sends(have: &[Tile], need: &[Tile], from: usize) -> Vec<(usize, Region)> {
    let mut out = Vec::new();
    for (to, nb) in need.iter().enumerate() {
        if to == from {
            continue;
        }
        for ra in &have[from] {
            for rb in nb {
                let ov = ra.intersect(rb);
                if !ov.is_empty() {
                    out.push((to, ov));
                }
            }
        }
    }
    out
}

/// How many patches `to` receives from all peers at `boundary`, given the
/// deterministic send rule (one patch per non-empty rect intersection).
pub(crate) fn expected_patches(have: &[Tile], need: &[Tile], to: usize) -> usize {
    (0..have.len())
        .filter(|&from| from != to)
        .map(|from| boundary_sends(have, need, from).iter().filter(|(t, _)| *t == to).count())
        .sum()
}

/// One node's lockstep protocol run, generic over the message fabric.
/// `input` is `Some` only on the leader (logical node 0), which owns
/// scatter and gather; in process mode the coordinator hands the input to
/// the leader daemon alone. Any transport failure — a dead peer, a missed
/// deadline — aborts the run with a typed error; the caller decides whether
/// that is a panic (deterministic in-process mode, where it can only be a
/// bug) or an explicit per-request failure (process mode under chaos).
#[allow(clippy::too_many_arguments)]
pub(crate) fn node_main<E: Exchange>(
    node: usize,
    nodes: usize,
    model: &Model,
    blocks: &[(usize, usize, Scheme)],
    geos: &[BlockGeometry],
    weights: &WeightStore,
    input: Option<&Tensor>,
    ex: &mut E,
    cfg: &ComputeConfig,
) -> Result<NodeResult, TransportError> {
    let layers = &model.layers;
    let n = layers.len();
    let mut sent_bytes = 0u64;
    let mut sent_msgs = 0usize;
    let mut traffic = vec![BoundaryTraffic::default(); blocks.len() + 1];
    let mut boundary = 0usize; // scatter = 0, after block b = b+1
    let mut arena = TensorArena::new(cfg.reuse_buffers);
    let mut items: Vec<(usize, Region)> = Vec::new();

    // --- scatter -----------------------------------------------------------
    let l0 = &layers[0];
    let full_in = Region::full(l0.in_h, l0.in_w, l0.in_c);
    let mut store = PatchStore::new();
    {
        let entry_need = &geos[0].entry_need;
        if node == 0 {
            let input = input.expect("leader requires the input tensor");
            let whole = RegionTensor::new(full_in, input.clone());
            // keep own requirement locally
            store.add(whole.clone());
            for (to, need) in entry_need.iter().enumerate().skip(1) {
                for r in need {
                    let patch = whole.slice(&r.intersect(&full_in));
                    if patch.region.is_empty() {
                        continue;
                    }
                    sent_bytes += patch.t.numel() as u64 * DTYPE_BYTES;
                    sent_msgs += 1;
                    traffic[boundary].bytes += patch.t.numel() as u64 * DTYPE_BYTES;
                    traffic[boundary].msgs += 1;
                    ex.send(to, boundary, patch)?;
                }
            }
        } else {
            let expect: usize = entry_need[node]
                .iter()
                .filter(|r| !r.intersect(&full_in).is_empty())
                .count();
            ex.recv_for(boundary, expect, &mut store)?;
        }
    }
    boundary += 1;

    // --- blocks ------------------------------------------------------------
    for (bi, &(s, e, scheme)) in blocks.iter().enumerate() {
        let geo = &geos[bi];
        // compute layers s..=e on the (inflated) tiles — the tile set fans
        // out over cfg.tile_workers and merges back in tile order
        for l in s..=e {
            let layer = &layers[l];
            items.clear();
            items.extend(geo.tiles[l - s][node].iter().map(|r| (0usize, *r)));
            let outs =
                compute_tile_set(layer, &weights.layers[l], &[&store], &items, cfg, &mut arena);
            let mut next = PatchStore::new();
            for o in outs {
                if o.region.is_empty() {
                    arena.give(o.t);
                } else {
                    next.add(o);
                }
            }
            arena.give_store(&mut store);
            store = next;
        }
        // boundary out of this block
        let producer = &layers[e];
        let have = out_tiles(producer, scheme, nodes);
        if e == n - 1 {
            // gather to leader
            if node != 0 {
                for rt in &store.patches {
                    sent_bytes += rt.t.numel() as u64 * DTYPE_BYTES;
                    sent_msgs += 1;
                    traffic[boundary].bytes += rt.t.numel() as u64 * DTYPE_BYTES;
                    traffic[boundary].msgs += 1;
                    ex.send(0, boundary, rt.clone())?;
                }
            } else {
                let expect: usize = (1..nodes)
                    .map(|other| have[other].iter().filter(|r| !r.is_empty()).count())
                    .sum();
                let mut gathered = store;
                ex.recv_for(boundary, expect, &mut gathered)?;
                let last = &layers[n - 1];
                let full = Region::full(last.out_h, last.out_w, last.out_c);
                let out = gathered.extract(&full, &full, true);
                return Ok(NodeResult { output: Some(out), sent_bytes, sent_msgs, traffic });
            }
        } else {
            let need: Vec<Tile> = geos[bi + 1].entry_need.clone();
            // send: my canonical tiles ∩ everyone's needs
            for (to, ov) in boundary_sends(&have, &need, node) {
                // extract the patch data (store holds this block's outputs,
                // which cover the canonical tile) into a recycled buffer;
                // `ov` is non-empty by construction
                let mut dense = arena.take(0, 0, 0);
                store.extract_into(&ov, &ov, true, &mut dense);
                let patch = RegionTensor::new(ov, dense);
                sent_bytes += patch.t.numel() as u64 * DTYPE_BYTES;
                sent_msgs += 1;
                traffic[boundary].bytes += patch.t.numel() as u64 * DTYPE_BYTES;
                traffic[boundary].msgs += 1;
                ex.send(to, boundary, patch)?;
            }
            // receive + keep own data
            let expect = expected_patches(&have, &need, node);
            let mut next = PatchStore::new();
            for p in store.patches.drain(..) {
                next.add(p);
            }
            ex.recv_for(boundary, expect, &mut next)?;
            store = next;
        }
        boundary += 1;
    }
    Ok(NodeResult { output: None, sent_bytes, sent_msgs, traffic })
}

/// How often a blocked `recv_for` wakes to check peer liveness.
const SIM_TICK: Duration = Duration::from_millis(1);

/// The in-process fabric: mpsc channels between node threads, with the
/// Mailbox reordering rule (a fast peer may already send patches for a
/// *later* boundary while this node still waits on the current one, so
/// messages tagged ahead are buffered; messages tagged behind are protocol
/// violations). This is the deterministic default used by tests, CI, and
/// every pre-PR-6 entry point.
///
/// Chaos tooling can hand the exchange a shared `dead` mask: while blocked
/// in `recv_for`, the wait wakes every [`SIM_TICK`] and surfaces any peer
/// flagged dead as [`TransportError::PeerDead`] — *mid-batch*, mirroring
/// how the TCP fabric detects missed heartbeats without waiting for the
/// batch boundary.
pub struct SimExchange {
    node: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    pending: Vec<Msg>,
    dead: Option<Arc<Vec<AtomicBool>>>,
    deadline: Duration,
}

/// Build a fully-connected in-process mesh of `nodes` [`SimExchange`]
/// endpoints with a bounded per-wait `deadline` — the fabric handle the
/// wire-fault injector ([`crate::transport::fault`]) wraps to replay a
/// `FaultSchedule` against the simulated transport.
pub(crate) fn sim_mesh(nodes: usize, deadline: Duration) -> Vec<SimExchange> {
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(nodes);
    let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let (tx, rx) = channel::<Msg>();
        senders.push(tx);
        receivers.push(Some(rx));
    }
    (0..nodes)
        .map(|node| {
            let mut ex = SimExchange::new(node, senders.clone(), receivers[node].take().unwrap());
            ex.deadline = deadline;
            ex
        })
        .collect()
}

impl SimExchange {
    fn new(node: usize, txs: Vec<Sender<Msg>>, rx: Receiver<Msg>) -> SimExchange {
        SimExchange {
            node,
            txs,
            rx,
            pending: Vec::new(),
            dead: None,
            // effectively unbounded: in deterministic mode a stall is a bug,
            // and the protocol has no lost-message mode
            deadline: Duration::from_secs(3600),
        }
    }

    /// Same fabric with failure injection: `dead[i]` flips when peer `i`
    /// "dies", and `deadline` bounds any single wait.
    fn with_liveness(
        node: usize,
        txs: Vec<Sender<Msg>>,
        rx: Receiver<Msg>,
        dead: Arc<Vec<AtomicBool>>,
        deadline: Duration,
    ) -> SimExchange {
        SimExchange { node, txs, rx, pending: Vec::new(), dead: Some(dead), deadline }
    }

    fn dead_peer(&self) -> Option<usize> {
        let dead = self.dead.as_ref()?;
        dead.iter()
            .enumerate()
            .find(|&(i, d)| i != self.node && d.load(Ordering::SeqCst))
            .map(|(i, _)| i)
    }
}

impl Exchange for SimExchange {
    fn send(
        &mut self,
        to: usize,
        boundary: usize,
        patch: RegionTensor,
    ) -> Result<(), TransportError> {
        self.txs[to].send(Msg { boundary, patch }).map_err(|_| TransportError::PeerDead(to))
    }

    /// Receive exactly `expect` patches tagged `boundary` into `store`.
    fn recv_for(
        &mut self,
        boundary: usize,
        expect: usize,
        store: &mut PatchStore,
    ) -> Result<(), TransportError> {
        let mut got = 0usize;
        // drain previously buffered patches for this boundary
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].boundary == boundary {
                let msg = self.pending.swap_remove(i);
                store.add(msg.patch);
                got += 1;
            } else {
                i += 1;
            }
        }
        let start = Instant::now();
        while got < expect {
            if let Some(p) = self.dead_peer() {
                return Err(TransportError::PeerDead(p));
            }
            match self.rx.recv_timeout(SIM_TICK) {
                Ok(msg) => {
                    if msg.boundary == boundary {
                        store.add(msg.patch);
                        got += 1;
                    } else if msg.boundary > boundary {
                        self.pending.push(msg);
                    } else {
                        return Err(TransportError::Protocol(format!(
                            "stale message for boundary {} while at {boundary}",
                            msg.boundary
                        )));
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if start.elapsed() > self.deadline {
                        return Err(TransportError::Deadline { boundary, got, expect });
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::Protocol(
                        "all peers disconnected mid-protocol".into(),
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::run_reference;
    use crate::model::zoo;
    use crate::partition::Mode;

    fn check_plan(model: &Model, plan: &Plan, nodes: usize) {
        let ws = WeightStore::for_model(model, 11);
        let l0 = &model.layers[0];
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, 99);
        let reference = run_reference(model, &ws, &input);
        let run = run_distributed(model, plan, &ws, &input, nodes);
        let diff = reference.max_abs_diff(&run.output);
        assert_eq!(diff, 0.0, "distributed != reference (diff {diff})");
    }

    #[test]
    fn uniform_plans_match_reference() {
        let model = zoo::edgenet(16);
        for scheme in Scheme::ALL {
            for nodes in [2usize, 3, 4] {
                let plan = Plan::uniform(scheme, model.n_layers());
                check_plan(&model, &plan, nodes);
            }
        }
    }

    #[test]
    fn fused_plan_matches_reference() {
        let model = zoo::edgenet(16);
        let mut plan = Plan::uniform(Scheme::InH, model.n_layers());
        // fuse the first four layers (conv, dw, pw, conv)
        plan.steps[0].mode = Mode::NT;
        plan.steps[1].mode = Mode::NT;
        plan.steps[2].mode = Mode::NT;
        plan.validate().unwrap();
        check_plan(&model, &plan, 4);
    }

    #[test]
    fn mixed_scheme_plan_matches_reference() {
        let model = zoo::edgenet(16);
        let n = model.n_layers();
        let mut plan = Plan::uniform(Scheme::InH, n);
        plan.steps[2].scheme = Scheme::OutC;
        plan.steps[3].scheme = Scheme::Grid2d;
        plan.steps[4].scheme = Scheme::InW;
        plan.steps[6].scheme = Scheme::OutC;
        plan.validate().unwrap();
        check_plan(&model, &plan, 4);
    }

    #[test]
    fn grid_on_three_nodes_matches_reference() {
        // the imbalanced multi-rect tile case
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::Grid2d, model.n_layers());
        check_plan(&model, &plan, 3);
    }

    #[test]
    fn bytes_exchanged_positive_and_counted() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let input = Tensor::random(16, 16, 3, 2);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_distributed(&model, &plan, &ws, &input, 4);
        assert!(run.bytes_exchanged > 0);
        assert!(run.messages > 0);
    }

    #[test]
    fn boundary_traffic_decomposes_the_totals() {
        // the per-boundary measurement hook must tile the aggregate
        // accounting exactly: one entry per exchange boundary, summing to
        // the run totals, with scatter and gather both visibly non-empty
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let input = Tensor::random(16, 16, 3, 2);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_distributed(&model, &plan, &ws, &input, 4);
        assert_eq!(run.boundary_traffic.len(), plan.blocks().len() + 1);
        let bytes: u64 = run.boundary_traffic.iter().map(|t| t.bytes).sum();
        let msgs: u64 = run.boundary_traffic.iter().map(|t| t.msgs).sum();
        assert_eq!(bytes, run.bytes_exchanged, "boundary bytes don't tile the total");
        assert_eq!(msgs, run.messages as u64, "boundary messages don't tile the total");
        let scatter = run.boundary_traffic.first().unwrap();
        let gather = run.boundary_traffic.last().unwrap();
        assert!(scatter.bytes > 0, "scatter moved nothing");
        assert!(gather.bytes > 0, "gather moved nothing");
        // single-node runs move nothing at any boundary
        let solo = run_distributed(&model, &plan, &ws, &input, 1);
        assert!(solo.boundary_traffic.iter().all(|t| t.bytes == 0 && t.msgs == 0));
    }

    #[test]
    fn degraded_cluster_still_matches_reference() {
        // kill one of four nodes: the remaining three produce bit-identical
        // output (work redistributes; every element keeps one accumulation
        // order)
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let input = Tensor::random(16, 16, 3, 42);
        let reference = run_reference(&model, &ws, &input);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_degraded(&model, &plan, &ws, &input, &[true, true, false, true]);
        assert_eq!(reference.max_abs_diff(&run.output), 0.0);
    }

    #[test]
    fn dead_leader_cluster_still_matches_reference() {
        // kill node 0: the lowest-ranked survivor (original rank 1) compacts
        // to logical 0 and takes over scatter/gather — the numerics don't
        // change, because node identity only selects a tile index
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let input = Tensor::random(16, 16, 3, 42);
        let reference = run_reference(&model, &ws, &input);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let alive = [false, true, true, true];
        assert_eq!(super::election::elect_leader(&alive), Some(1));
        let run = run_degraded(&model, &plan, &ws, &input, &alive);
        assert_eq!(reference.max_abs_diff(&run.output), 0.0);
    }

    #[test]
    #[should_panic(expected = "no surviving nodes")]
    fn degraded_cluster_needs_a_survivor() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 1);
        let input = Tensor::random(16, 16, 3, 1);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        run_degraded(&model, &plan, &ws, &input, &[false, false]);
    }

    #[test]
    fn single_node_degenerate_cluster() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 11);
        let input = Tensor::random(16, 16, 3, 99);
        let reference = run_reference(&model, &ws, &input);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let run = run_distributed(&model, &plan, &ws, &input, 1);
        assert_eq!(reference.max_abs_diff(&run.output), 0.0);
        assert_eq!(run.bytes_exchanged, 0);
    }

    // --- mid-batch failure detection on the simulated fabric ------------

    #[test]
    fn sim_exchange_surfaces_peer_death_mid_wait() {
        // node 0 blocks waiting for a patch that will never come; a watcher
        // thread flips the dead mask 20ms in. recv_for must return
        // PeerDead(1) from *inside* the wait — mid-batch, not at a batch
        // boundary — and well before the overall deadline.
        let (_tx0, rx0) = channel::<Msg>();
        let (tx1, _rx1) = channel::<Msg>();
        let (tx0b, _) = channel::<Msg>();
        let dead: Arc<Vec<AtomicBool>> = Arc::new((0..2).map(|_| AtomicBool::new(false)).collect());
        let mut ex = SimExchange::with_liveness(
            0,
            vec![tx0b, tx1],
            rx0,
            Arc::clone(&dead),
            Duration::from_secs(10),
        );
        let killer = {
            let dead = Arc::clone(&dead);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                dead[1].store(true, Ordering::SeqCst);
            })
        };
        let start = Instant::now();
        let mut store = PatchStore::new();
        let err = ex.recv_for(1, 1, &mut store).unwrap_err();
        assert_eq!(err, TransportError::PeerDead(1));
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "death detected only after the deadline, not mid-wait"
        );
        killer.join().unwrap();
    }

    #[test]
    fn sim_exchange_deadline_is_typed_not_a_hang() {
        // nobody dies and nobody sends: the bounded wait must end in a
        // typed Deadline error carrying the progress made
        let (_tx0, rx0) = channel::<Msg>();
        let (tx1, _rx1) = channel::<Msg>();
        let (tx0b, _) = channel::<Msg>();
        let dead: Arc<Vec<AtomicBool>> = Arc::new((0..2).map(|_| AtomicBool::new(false)).collect());
        let mut ex =
            SimExchange::with_liveness(0, vec![tx0b, tx1], rx0, dead, Duration::from_millis(30));
        let mut store = PatchStore::new();
        let err = ex.recv_for(2, 3, &mut store).unwrap_err();
        assert_eq!(err, TransportError::Deadline { boundary: 2, got: 0, expect: 3 });
    }

    #[test]
    fn sim_exchange_send_to_dead_peer_is_typed() {
        let (_tx0, rx0) = channel::<Msg>();
        let (tx1, rx1) = channel::<Msg>();
        let (tx0b, _) = channel::<Msg>();
        drop(rx1); // peer 1's receiver is gone — as after a thread death
        let mut ex = SimExchange::new(0, vec![tx0b, tx1], rx0);
        let r = Region::new(0, 1, 0, 1, 0, 1);
        let patch = RegionTensor::new(r, Tensor::zeros(1, 1, 1));
        let err = ex.send(1, 0, patch).unwrap_err();
        assert_eq!(err, TransportError::PeerDead(1));
    }
}
