//! Baseline partition strategies (paper §4 "Baselines").
//!
//! | paper baseline | systems | implementation |
//! |---|---|---|
//! | One-dim (OutC) | Xenos | [`fixed`] with [`Scheme::OutC`] |
//! | One-dim (InH/InW) | MoDNN, DeepSlicing | [`one_dim_best`] — the better of InH / InW for the model (the papers pick one spatial axis) |
//! | 2D-grid | DeepThings | [`fixed`] with [`Scheme::Grid2d`] |
//! | layerwise | DINA, PartialDI | [`layerwise`] — per-layer scheme choice, **no fusion** (DPP restricted to span-1 blocks) |
//! | fused-layer | AOFL, EdgeCI | [`fused_layer`] — fusion (T/NT) optimization over a **single fixed scheme** (the best fixed one) |
//!
//! All baselines emit ordinary [`Plan`]s, costed/executed by the same engine
//! as FlexPie — the comparison differences are purely in planning freedom.

use crate::cost::CostSource;
use crate::model::Model;
use crate::partition::{Plan, Scheme};
use crate::planner::exhaustive::plan_cost;
use crate::planner::{Dpp, DppConfig};

/// All six solutions of the paper's evaluation, in figure order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Solution {
    OutC,
    OneDim,
    Grid2d,
    Layerwise,
    FusedLayer,
    FlexPie,
}

impl Solution {
    pub const ALL: [Solution; 6] = [
        Solution::OutC,
        Solution::OneDim,
        Solution::Grid2d,
        Solution::Layerwise,
        Solution::FusedLayer,
        Solution::FlexPie,
    ];

    /// The five baselines (everything but FlexPie).
    pub const BASELINES: [Solution; 5] = [
        Solution::OutC,
        Solution::OneDim,
        Solution::Grid2d,
        Solution::Layerwise,
        Solution::FusedLayer,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Solution::OutC => "One-dim(OutC)",
            Solution::OneDim => "One-dim(InH/InW)",
            Solution::Grid2d => "2D-grid",
            Solution::Layerwise => "Layerwise",
            Solution::FusedLayer => "Fused-layer",
            Solution::FlexPie => "FlexPie",
        }
    }

    /// Produce this solution's plan for `model` under `cost`.
    pub fn plan(self, model: &Model, cost: &CostSource) -> Plan {
        match self {
            Solution::OutC => fixed(model, Scheme::OutC, cost),
            Solution::OneDim => one_dim_best(model, cost),
            Solution::Grid2d => fixed(model, Scheme::Grid2d, cost),
            Solution::Layerwise => layerwise(model, cost),
            Solution::FusedLayer => fused_layer(model, cost),
            Solution::FlexPie => Dpp::new(model, cost).plan(),
        }
    }
}

impl std::fmt::Display for Solution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Fixed single-scheme plan, all-T (Xenos / DeepThings style).
pub fn fixed(model: &Model, scheme: Scheme, cost: &CostSource) -> Plan {
    let mut plan = Plan::uniform(scheme, model.n_layers());
    plan.est_cost = plan_cost(model, &plan, cost).total;
    plan
}

/// The better of the two One-dim spatial axes for this model (MoDNN and
/// DeepSlicing pick a single spatial split axis for the whole model).
pub fn one_dim_best(model: &Model, cost: &CostSource) -> Plan {
    let h = fixed(model, Scheme::InH, cost);
    let w = fixed(model, Scheme::InW, cost);
    if h.est_cost <= w.est_cost {
        h
    } else {
        w
    }
}

/// Layerwise optimization (DINA / PartialDI): every layer independently
/// chooses its scheme, but every boundary transmits (no fusion). Implemented
/// as the DP restricted to single-layer blocks — which makes it *optimal*
/// within that search space, a generous reading of the baseline.
pub fn layerwise(model: &Model, cost: &CostSource) -> Plan {
    Dpp::with_config(
        model,
        cost,
        DppConfig { enable_fusion: false, ..Default::default() },
    )
    .plan()
}

/// Fused-layer optimization (AOFL / EdgeCI): T/NT fusion decisions over a
/// single fixed partition scheme (the scheme itself is chosen as the best
/// fixed baseline, mirroring how those systems fuse on top of their native
/// partitioning).
pub fn fused_layer(model: &Model, cost: &CostSource) -> Plan {
    let mut best: Option<Plan> = None;
    for scheme in [Scheme::InH, Scheme::InW, Scheme::Grid2d, Scheme::OutC] {
        let plan = Dpp::with_config(
            model,
            cost,
            DppConfig { schemes: vec![scheme], ..Default::default() },
        )
        .plan();
        if best.as_ref().map(|b| plan.est_cost < b.est_cost).unwrap_or(true) {
            best = Some(plan);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Testbed, Topology};
    use crate::partition::Mode;

    fn analytic(nodes: usize, gbps: f64) -> CostSource {
        CostSource::analytic(&Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(gbps)))
    }

    #[test]
    fn all_solutions_produce_valid_plans() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        for sol in Solution::ALL {
            let plan = sol.plan(&model, &cost);
            plan.validate().unwrap();
            assert_eq!(plan.steps.len(), model.n_layers(), "{sol}");
            assert!(plan.est_cost.is_finite() && plan.est_cost > 0.0, "{sol}");
        }
    }

    #[test]
    fn layerwise_has_no_fusion() {
        let cost = analytic(4, 0.2);
        let model = zoo::edgenet(16);
        let plan = layerwise(&model, &cost);
        assert!(plan.steps.iter().all(|s| s.mode == Mode::T));
    }

    #[test]
    fn fused_layer_uses_single_scheme() {
        let cost = analytic(4, 0.2);
        let model = zoo::edgenet(16);
        let plan = fused_layer(&model, &cost);
        let first = plan.steps[0].scheme;
        assert!(plan.steps.iter().all(|s| s.scheme == first));
    }

    #[test]
    fn flexpie_dominates_all_baselines_in_estimate() {
        // FlexPie's search space is a superset of every baseline's, so under
        // the same (analytic) cost source its estimated cost must be ≤ all.
        for gbps in [5.0, 0.5] {
            for nodes in [3usize, 4] {
                let cost = analytic(nodes, gbps);
                let model = zoo::mobilenet_v1(224, 1000).truncated(9);
                let flex = Solution::FlexPie.plan(&model, &cost);
                for sol in Solution::BASELINES {
                    let b = sol.plan(&model, &cost);
                    assert!(
                        flex.est_cost <= b.est_cost + 1e-9,
                        "{sol} ({}) beat FlexPie ({}) at {gbps}Gb/s n={nodes}",
                        b.est_cost,
                        flex.est_cost
                    );
                }
            }
        }
    }

    #[test]
    fn layerwise_beats_fixed_schemes() {
        // Layerwise optimization subsumes every fixed scheme.
        let cost = analytic(4, 1.0);
        let model = zoo::mobilenet_v1(224, 1000).truncated(9);
        let lw = layerwise(&model, &cost);
        for s in Scheme::ALL {
            let f = fixed(&model, s, &cost);
            assert!(lw.est_cost <= f.est_cost + 1e-9);
        }
    }

    #[test]
    fn one_dim_picks_the_better_axis() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let best = one_dim_best(&model, &cost);
        let h = fixed(&model, Scheme::InH, &cost);
        let w = fixed(&model, Scheme::InW, &cost);
        assert_eq!(best.est_cost, h.est_cost.min(w.est_cost));
    }
}
