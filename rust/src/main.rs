//! `flexpie` — CLI for the FlexPie distributed-inference framework.
//!
//! ```text
//! flexpie zoo                                    list models
//! flexpie plan      --model mobilenet --nodes 4 --topology ring --bw 5gbps [--cost gbdt]
//! flexpie evaluate  --model mobilenet --nodes 4 ...      all six solutions
//! flexpie verify    --model edgenet --nodes 4    execute distributed vs reference
//! flexpie trace-gen --samples 60000 --out artifacts/traces.json
//! flexpie train-ce  --samples 60000 [--trees 300] --out artifacts/ce
//! flexpie bench     --fig 2|7|8|9 | --search-time | --ablation [--cost analytic]
//! flexpie serve     --model edgenet --requests 64 --batch 8 [--profile diurnal-drift --seed 7]
//! ```

use std::sync::Arc;

use flexpie::baselines::Solution;
use flexpie::bench::{BenchOpts, CostKind};
use flexpie::compute::{Tensor, WeightStore};
use flexpie::cost::estimator::Estimators;
use flexpie::cost::gbdt::GbdtParams;
use flexpie::cost::tracegen::{self, TraceConfig};
use flexpie::cost::CostSource;
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Testbed, Topology};
use flexpie::planner::Dpp;
use flexpie::serve::{ServeConfig, Server};
use flexpie::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("zoo") => cmd_zoo(),
        Some("plan") => cmd_plan(&args),
        Some("evaluate") => cmd_evaluate(&args),
        Some("verify") => cmd_verify(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        Some("train-ce") => cmd_train_ce(&args),
        Some("bench") => cmd_bench(&args),
        Some("export-model") => cmd_export_model(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => {
            eprintln!("unknown command {other:?}");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "flexpie — distributed edge inference with flexible combinatorial optimization\n\
         commands: zoo | plan | evaluate | verify | trace-gen | train-ce | bench | serve | export-model\n\
         common flags: --model <name> --nodes <n> --topology ring|ps|mesh --bw 5gbps|500mbps\n\
         see README.md for full usage"
    );
}

/// Resolve a model from `--model <zoo-name>` or `--model-file <path>` (the
/// JSON import format of `model::import`).
fn model_from(args: &Args, default: &str) -> Option<flexpie::model::Model> {
    if let Some(path) = args.get("model-file") {
        match flexpie::model::import::load(std::path::Path::new(path)) {
            Ok((model, stats)) => {
                println!(
                    "imported {} ({} layers; folded {} BN, {} act, {} residual)",
                    model.name, model.n_layers(), stats.bn_folded,
                    stats.activations_fused, stats.residuals_folded
                );
                return Some(model);
            }
            Err(e) => {
                eprintln!("model import failed: {e}");
                return None;
            }
        }
    }
    zoo::by_name(args.get_or("model", default))
}

fn testbed_from(args: &Args) -> Testbed {
    let nodes = args.usize_or("nodes", 4);
    let topology: Topology = args.get_or("topology", "ring").parse().unwrap_or(Topology::Ring);
    let bw = args.bandwidth_or("bw", 5.0);
    Testbed::new(nodes, topology, bw)
}

fn cost_from(args: &Args, tb: &Testbed) -> CostSource {
    match args.get_or("cost", "analytic") {
        "gbdt" => {
            let dir = std::path::PathBuf::from(args.get_or("ce", "artifacts/ce"));
            let cfg =
                TraceConfig { samples: args.usize_or("samples", 20_000), ..Default::default() };
            let params = GbdtParams { n_trees: args.usize_or("trees", 200), ..Default::default() };
            let (est, report) =
                Estimators::load_or_train(&dir, &cfg, &params).expect("train/load CE");
            if let Some(r) = report {
                println!(
                    "trained CE: i-Est r2={:.3} ρ={:.3}; s-Est r2={:.3} ρ={:.3}",
                    r.i_fit.r2, r.i_fit.spearman, r.s_fit.r2, r.s_fit.spearman
                );
            }
            CostSource::gbdt(est, tb)
        }
        _ => CostSource::analytic(tb),
    }
}

fn cmd_zoo() -> i32 {
    let mut t = flexpie::util::bench::Table::new(["model", "layers", "GFLOPs", "params (M)"]);
    for m in zoo::paper_benchmarks().iter().chain([zoo::edgenet(16)].iter()) {
        t.row([
            m.name.clone(),
            m.n_layers().to_string(),
            format!("{:.2}", m.total_flops() / 1e9),
            format!("{:.2}", m.total_params() as f64 / 1e6),
        ]);
    }
    t.print();
    0
}

fn cmd_plan(args: &Args) -> i32 {
    let Some(model) = model_from(args, "mobilenet") else {
        eprintln!("unknown model (use --model <zoo> or --model-file <path>)");
        return 2;
    };
    let tb = testbed_from(args);
    let cost = cost_from(args, &tb);
    let dpp = Dpp::new(&model, &cost);
    let (plan, stats) = dpp.plan_with_stats();
    println!(
        "model={} nodes={} topo={} bw={:.2}Gb/s cost={}",
        model.name,
        tb.nodes,
        tb.topology,
        tb.bandwidth.as_gbps(),
        cost.name()
    );
    println!("plan: {}", plan.render());
    println!(
        "estimated {:.3} ms | search {:.1} ms, {} i-queries, {} s-queries, {} pruned",
        plan.est_cost * 1e3,
        stats.elapsed.as_secs_f64() * 1e3,
        stats.compute_queries,
        stats.sync_queries,
        stats.candidates_pruned
    );
    let report = engine::evaluate(&model, &plan, &tb);
    println!(
        "simulator: total {:.3} ms (compute {:.3} ms, sync {:.3} ms, {:.2} MB moved)",
        report.total_ms(),
        report.compute * 1e3,
        report.sync * 1e3,
        report.bytes_moved as f64 / 1e6
    );
    0
}

fn cmd_evaluate(args: &Args) -> i32 {
    let Some(model) = model_from(args, "mobilenet") else {
        eprintln!("unknown model (use --model <zoo> or --model-file <path>)");
        return 2;
    };
    let tb = testbed_from(args);
    let cost = cost_from(args, &tb);
    let mut t =
        flexpie::util::bench::Table::new(["solution", "time (ms)", "plan (first 6 steps)"]);
    for sol in Solution::ALL {
        let plan = sol.plan(&model, &cost);
        let report = engine::evaluate(&model, &plan, &tb);
        let prefix: Vec<String> =
            plan.steps.iter().take(6).map(|s| format!("{}·{}", s.scheme, s.mode)).collect();
        t.row([sol.name().to_string(), format!("{:.3}", report.total_ms()), prefix.join(" ")]);
    }
    t.print();
    0
}

fn cmd_verify(args: &Args) -> i32 {
    let Some(model) = model_from(args, "edgenet") else {
        eprintln!("unknown model (use --model <zoo> or --model-file <path>)");
        return 2;
    };
    if model.total_flops() > 5e9 {
        eprintln!(
            "warning: {} is large for real-numerics verification; consider --model edgenet",
            model.name
        );
    }
    let tb = testbed_from(args);
    let cost = cost_from(args, &tb);
    let plan = Dpp::new(&model, &cost).plan();
    println!("plan: {}", plan.render());
    let diff = engine::verify_plan(&model, &plan, &tb, args.u64_or("seed", 7));
    println!("max |distributed - reference| = {diff}");
    if diff == 0.0 {
        println!("verify OK");
        0
    } else {
        eprintln!("verify FAILED");
        1
    }
}

fn cmd_trace_gen(args: &Args) -> i32 {
    let cfg = TraceConfig {
        samples: args.usize_or("samples", 60_000),
        noise_sigma: args.f64_or("noise", 0.04),
        seed: args.u64_or("seed", 0x7ace),
        max_block: args.usize_or("max-block", 5),
    };
    let out = std::path::PathBuf::from(args.get_or("out", "artifacts/traces.json"));
    let t0 = std::time::Instant::now();
    let traces = tracegen::generate(&cfg);
    println!(
        "generated {} compute + {} sync samples in {:.1}s",
        traces.compute.len(),
        traces.sync.len(),
        t0.elapsed().as_secs_f64()
    );
    traces.save(&out).expect("save traces");
    println!("wrote {}", out.display());
    0
}

fn cmd_train_ce(args: &Args) -> i32 {
    let params = GbdtParams {
        n_trees: args.usize_or("trees", 300),
        max_depth: args.usize_or("depth", 7),
        ..Default::default()
    };
    let out = std::path::PathBuf::from(args.get_or("out", "artifacts/ce"));
    let t0 = std::time::Instant::now();
    let (est, report) = if let Some(traces_path) = args.get("traces") {
        let traces =
            tracegen::Traces::load(std::path::Path::new(traces_path)).expect("load traces");
        Estimators::train(&traces, &params)
    } else {
        let cfg = TraceConfig { samples: args.usize_or("samples", 60_000), ..Default::default() };
        Estimators::train_from_scratch(&cfg, &params)
    };
    println!(
        "trained in {:.1}s\n  i-Estimator: r2={:.4} mare={:.4} spearman={:.4} (n={})\n  s-Estimator: r2={:.4} mare={:.4} spearman={:.4} (n={})",
        t0.elapsed().as_secs_f64(),
        report.i_fit.r2,
        report.i_fit.mare,
        report.i_fit.spearman,
        report.i_fit.n,
        report.s_fit.r2,
        report.s_fit.mare,
        report.s_fit.spearman,
        report.s_fit.n
    );
    est.save(&out).expect("save estimators");
    println!("wrote {}/i_est.json and s_est.json", out.display());
    0
}

fn cmd_bench(args: &Args) -> i32 {
    let mut opts = BenchOpts {
        cost: match args.get_or("cost", "gbdt") {
            "analytic" => CostKind::Analytic,
            _ => CostKind::Gbdt,
        },
        ..Default::default()
    };
    if let Some(t) = args.get("truncate") {
        opts.truncate = t.parse().unwrap_or(0);
    }
    use flexpie::bench as b;
    if args.has("search-time") {
        b::search_time_table(&b::search_time(&opts)).print();
        return 0;
    }
    if args.has("ablation") {
        b::ablation_table(&b::ablation(&opts)).print();
        return 0;
    }
    if args.has("scaling") {
        b::scaling_table(&b::scaling(&opts)).print();
        return 0;
    }
    match args.get_or("fig", "all") {
        "2" => b::fig2_table(&b::fig2(&opts)).print(),
        "7" => {
            for (title, t) in b::fig7_9_tables(&b::fig7_9(4, &opts)) {
                println!("\n== Fig 7 [{title}] ==");
                t.print();
            }
        }
        "9" => {
            for (title, t) in b::fig7_9_tables(&b::fig7_9(3, &opts)) {
                println!("\n== Fig 9 [{title}] ==");
                t.print();
            }
        }
        "8" => {
            let c4 = b::fig7_9(4, &opts);
            let c3 = b::fig7_9(3, &opts);
            let s4 = b::fig8(&c4, &opts);
            let s3 = b::fig8(&c3, &opts);
            b::fig8_table(&s4, &s3).print();
        }
        _ => {
            println!("== Fig 2 ==");
            b::fig2_table(&b::fig2(&opts)).print();
            let c4 = b::fig7_9(4, &opts);
            for (title, t) in b::fig7_9_tables(&c4) {
                println!("\n== Fig 7 [{title}] ==");
                t.print();
            }
            let c3 = b::fig7_9(3, &opts);
            for (title, t) in b::fig7_9_tables(&c3) {
                println!("\n== Fig 9 [{title}] ==");
                t.print();
            }
            let s4 = b::fig8(&c4, &opts);
            let s3 = b::fig8(&c3, &opts);
            println!("\n== Fig 8 ==");
            b::fig8_table(&s4, &s3).print();
            println!("\n== DPP search time ==");
            b::search_time_table(&b::search_time(&opts)).print();
        }
    }
    0
}

/// Export a zoo model to the JSON import format (round-trip with
/// `--model-file`): `flexpie export-model --model mobilenet --out m.json`.
fn cmd_export_model(args: &Args) -> i32 {
    let Some(model) = zoo::by_name(args.get_or("model", "edgenet")) else {
        eprintln!("unknown model");
        return 2;
    };
    let out = std::path::PathBuf::from(
        args.get_or("out", &format!("{}.flexpie.json", model.name)),
    );
    let json = flexpie::model::import::export_json(&model);
    if let Err(e) = json.save(&out) {
        eprintln!("write failed: {e}");
        return 1;
    }
    println!("wrote {} ({} layers)", out.display(), model.n_layers());
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(model) = model_from(args, "edgenet") else {
        eprintln!("unknown model (use --model <zoo> or --model-file <path>)");
        return 2;
    };
    let tb = testbed_from(args);
    let weights = WeightStore::for_model(&model, 42);
    let cfg = ServeConfig {
        max_batch: args.usize_or("batch", 8),
        batch_window: std::time::Duration::from_millis(args.u64_or("window-ms", 2)),
        queue_depth: args.usize_or("queue", 128),
        pipeline_depth: args.usize_or("pipeline-depth", 1),
        replay_budget: args.u64_or("replay-budget", 3) as u32,
        compute: flexpie::compute::ComputeConfig {
            tile_workers: args.usize_or("tile-workers", 2),
            ..Default::default()
        },
    };
    // `--profile <stable|diurnal-drift|lossy-link|node-churn>` switches to
    // the elastic (condition-aware) serving path.
    let server = if let Some(profile) = args.get("profile") {
        if args.has("cost") {
            eprintln!(
                "note: --cost is ignored with --profile (elastic replanning \
                 uses the analytic cost model)"
            );
        }
        let exp = flexpie::config::ElasticExperiment {
            profile: profile.to_string(),
            seed: args.u64_or("seed", 7),
            ..Default::default()
        };
        let trace = match exp.trace(tb.nodes) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        println!("serving {} elastically under the {} profile", model.name, exp.profile);
        Server::start_elastic(model.clone(), weights, tb, trace, cfg, exp.controller_config())
    } else {
        let cost = cost_from(args, &tb);
        let plan = Dpp::new(&model, &cost).plan();
        println!("serving {} with plan: {}", model.name, plan.render());
        Server::start(model.clone(), plan, weights, tb, cfg)
    };
    let server = Arc::new(server);

    let n_requests = args.usize_or("requests", 64);
    let l0 = &model.layers[0];
    let t0 = std::time::Instant::now();
    let mut lat = Vec::new();
    let mut vtimes = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, i as u64);
        match server.submit(input) {
            Ok(rx) => rxs.push((std::time::Instant::now(), rx)),
            Err(e) => eprintln!("request {i} rejected: {e:?}"),
        }
    }
    for (t_submit, rx) in rxs {
        if let Ok(resp) = rx.recv() {
            lat.push(t_submit.elapsed());
            vtimes.push(resp.virtual_time);
        }
    }
    let wall = t0.elapsed();
    println!("latency (host wall-clock): {}", flexpie::metrics::summarize(&lat));
    println!(
        "throughput: {:.1} req/s (host) | per-request simulated inference: {:.3} ms",
        lat.len() as f64 / wall.as_secs_f64(),
        vtimes.first().copied().unwrap_or(0.0) * 1e3
    );
    let server = Arc::try_unwrap(server).ok().expect("server still shared");
    let stats = server.shutdown();
    println!(
        "router: {} requests in {} batches (max batch {})",
        stats.requests, stats.batches, stats.max_batch_seen
    );
    if let Some(p) = stats.pipeline {
        println!("pipeline: {p}");
    }
    if let Some(m) = stats.adaptation {
        println!("adaptation: {m}");
    }
    0
}
