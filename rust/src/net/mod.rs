//! Network simulator — the SRIO-interconnect substitute.
//!
//! The paper's testbed connects 4 TMS320C6678 DSPs over SRIO at 5 Gb/s /
//! 1 Gb/s / 500 Mb/s, under three communication architectures: Ring-based,
//! parameter-server (PS)-based and Mesh-based. We model the interconnect at
//! message level: a boundary exchange is a byte matrix `msgs[a][b]` (from
//! [`crate::partition::geometry::boundary_messages`]) and the topology turns
//! it into elapsed time by scheduling the messages over its links:
//!
//! * **Mesh** — a dedicated full-duplex link per node pair; a node's TX and
//!   RX ports serialize their own traffic, so the exchange takes the busiest
//!   port's time.
//! * **Ring** — messages travel the shortest arc; each directed ring link
//!   serializes everything routed through it.
//! * **PS** — all traffic is relayed through the parameter server (node 0);
//!   the server's single full-duplex port is the bottleneck.
//!
//! Per-message latency models SRIO doorbell + DMA setup cost.

/// Communication architecture (the paper's "Arch" categorical feature).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    Ring,
    /// Parameter-server (star) — node 0 is the server/leader.
    Ps,
    Mesh,
}

impl Topology {
    pub const ALL: [Topology; 3] = [Topology::Ring, Topology::Ps, Topology::Mesh];

    pub fn code(self) -> f64 {
        match self {
            Topology::Ring => 0.0,
            Topology::Ps => 1.0,
            Topology::Mesh => 2.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Topology::Ring => "Ring",
            Topology::Ps => "PS",
            Topology::Mesh => "Mesh",
        }
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Topology {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Ok(Topology::Ring),
            "ps" | "star" => Ok(Topology::Ps),
            "mesh" => Ok(Topology::Mesh),
            other => Err(format!("unknown topology {other:?}")),
        }
    }
}

/// Link bandwidth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bandwidth {
    bits_per_sec: f64,
}

impl Bandwidth {
    pub fn gbps(g: f64) -> Bandwidth {
        Bandwidth { bits_per_sec: g * 1e9 }
    }

    pub fn mbps(m: f64) -> Bandwidth {
        Bandwidth { bits_per_sec: m * 1e6 }
    }

    pub fn as_gbps(&self) -> f64 {
        self.bits_per_sec / 1e9
    }

    /// Seconds to move `bytes` over one link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        (bytes as f64 * 8.0) / self.bits_per_sec
    }

    /// This bandwidth scaled by `factor` — the hook the runtime-adaptation
    /// layer ([`crate::elastic`]) uses to model drifting link quality.
    pub fn scaled(self, factor: f64) -> Bandwidth {
        assert!(factor > 0.0 && factor.is_finite(), "bad bandwidth factor {factor}");
        Bandwidth { bits_per_sec: self.bits_per_sec * factor }
    }
}

/// Per-device compute profile — the TMS320C6678 substitute. The DSP peaks at
/// ~128 GFLOP/s (single precision, 8 cores); achievable efficiency varies by
/// op type (depthwise convs are memory-bound, matmuls near peak), which is
/// what makes different layers prefer different partition schemes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    /// Peak single-precision throughput, FLOP/s.
    pub peak_flops: f64,
    /// Achievable fraction of peak per op family, indexed by
    /// [`crate::model::ConvType::code`].
    pub efficiency: [f64; 6],
    /// Fixed per-layer overhead (kernel launch, DMA descriptor setup), s.
    pub layer_overhead: f64,
}

impl Default for DeviceProfile {
    fn default() -> Self {
        DeviceProfile {
            peak_flops: 128e9,
            // Standard, Depthwise, Pointwise, Dense, Attention, Pool
            efficiency: [0.55, 0.12, 0.50, 0.70, 0.60, 0.08],
            layer_overhead: 20e-6,
        }
    }
}

impl DeviceProfile {
    /// Seconds for this device to execute `flops` of op family `conv_t`.
    pub fn compute_time(&self, flops: f64, conv_t: crate::model::ConvType) -> f64 {
        if flops <= 0.0 {
            // A node with an empty tile still pays the sync barrier, not the
            // launch overhead.
            return 0.0;
        }
        let eff = self.efficiency[conv_t.code() as usize];
        flops / (self.peak_flops * eff) + self.layer_overhead
    }
}

/// A testbed: the cluster specification the planner adapts to.
#[derive(Debug, Clone, PartialEq)]
pub struct Testbed {
    pub nodes: usize,
    pub topology: Topology,
    pub bandwidth: Bandwidth,
    /// Per-message latency (doorbell + DMA setup), seconds.
    pub latency: f64,
    pub device: DeviceProfile,
    /// Per-node relative speed factors (1.0 = profile speed). Length must be
    /// `nodes`; heterogeneous clusters are an ablation.
    pub speed: Vec<f64>,
}

impl Testbed {
    pub fn new(nodes: usize, topology: Topology, bandwidth: Bandwidth) -> Testbed {
        assert!(nodes >= 1 && nodes <= 16, "edge clusters are small (got {nodes})");
        Testbed {
            nodes,
            topology,
            bandwidth,
            latency: 5e-6,
            device: DeviceProfile::default(),
            speed: vec![1.0; nodes],
        }
    }

    pub fn with_speed(mut self, speed: Vec<f64>) -> Testbed {
        assert_eq!(speed.len(), self.nodes);
        self.speed = speed;
        self
    }

    /// This testbed with every link's bandwidth scaled by `factor`
    /// (time-varying-conditions hook for [`crate::elastic`]).
    pub fn with_bandwidth_factor(&self, factor: f64) -> Testbed {
        let mut tb = self.clone();
        tb.bandwidth = tb.bandwidth.scaled(factor);
        tb
    }

    /// The surviving sub-cluster after removing the nodes marked dead in
    /// `alive` (length must equal `nodes`; at least one node must survive).
    /// Surviving nodes keep their per-node speed factors; node ids compact
    /// to `0..alive_count` in original order, so the leader role falls to
    /// the first survivor.
    pub fn subset(&self, alive: &[bool]) -> Testbed {
        assert_eq!(alive.len(), self.nodes, "alive mask length != nodes");
        let speed: Vec<f64> = self
            .speed
            .iter()
            .zip(alive)
            .filter_map(|(&s, &a)| a.then_some(s))
            .collect();
        assert!(!speed.is_empty(), "no surviving nodes");
        Testbed {
            nodes: speed.len(),
            topology: self.topology,
            bandwidth: self.bandwidth,
            latency: self.latency,
            device: self.device,
            speed,
        }
    }

    /// Elapsed time for the boundary exchange described by the byte matrix
    /// `msgs[a*nodes+b]` under this testbed's topology.
    pub fn exchange_time(&self, msgs: &[u64]) -> f64 {
        let n = self.nodes;
        debug_assert_eq!(msgs.len(), n * n);
        if msgs.iter().all(|&m| m == 0) {
            return 0.0;
        }
        self.price_exchange(&self.exchange_profile(msgs))
    }

    /// The bandwidth-independent schedule of a byte matrix under this
    /// testbed's topology: which bytes and how many distinct messages each
    /// serialized resource (directed link or node port) carries. Routing
    /// depends only on the topology, never on link speed, so a profile
    /// computed once can be re-priced under any bandwidth
    /// ([`Self::price_exchange`]) — the split [`crate::cost::memo`] exploits
    /// to re-price cached boundary geometry analytically on bandwidth drift.
    pub fn exchange_profile(&self, msgs: &[u64]) -> ExchangeProfile {
        let n = self.nodes;
        debug_assert_eq!(msgs.len(), n * n);
        match self.topology {
            Topology::Mesh => self.mesh_profile(msgs),
            Topology::Ring => self.ring_profile(msgs),
            Topology::Ps => self.ps_profile(msgs),
        }
    }

    /// Elapsed seconds of a profiled exchange under this testbed's *current*
    /// bandwidth and per-message latency: the busiest entry's
    /// `transfer_time(bytes) + latency · msgs`.
    pub fn price_exchange(&self, profile: &ExchangeProfile) -> f64 {
        let mut busiest = 0.0f64;
        for load in &profile.loads {
            busiest = busiest
                .max(self.bandwidth.transfer_time(load.bytes) + self.latency * load.msgs as f64);
        }
        busiest
    }

    /// Mesh: per-node TX/RX port serialization; latency per distinct message
    /// on the busiest port.
    fn mesh_profile(&self, msgs: &[u64]) -> ExchangeProfile {
        let n = self.nodes;
        let mut loads = Vec::with_capacity(2 * n);
        for node in 0..n {
            let (mut tx, mut rx) = (0u64, 0u64);
            let (mut tx_msgs, mut rx_msgs) = (0u64, 0u64);
            for other in 0..n {
                let out = msgs[node * n + other];
                let inc = msgs[other * n + node];
                tx += out;
                rx += inc;
                tx_msgs += (out > 0) as u64;
                rx_msgs += (inc > 0) as u64;
            }
            loads.push(PortLoad { bytes: tx, msgs: tx_msgs });
            loads.push(PortLoad { bytes: rx, msgs: rx_msgs });
        }
        ExchangeProfile { loads }
    }

    /// Ring: route each message along the shorter arc; every directed link
    /// serializes the bytes routed through it.
    fn ring_profile(&self, msgs: &[u64]) -> ExchangeProfile {
        let n = self.nodes;
        // link_cw[i]: i -> (i+1)%n ; link_ccw[i]: i -> (i-1+n)%n
        let mut link_cw = vec![0u64; n];
        let mut link_ccw = vec![0u64; n];
        let mut msgs_cw = vec![0u64; n];
        let mut msgs_ccw = vec![0u64; n];
        for a in 0..n {
            for b in 0..n {
                let bytes = msgs[a * n + b];
                if bytes == 0 || a == b {
                    continue;
                }
                let fwd = ((b + n) - a) % n; // hops clockwise
                let bwd = n - fwd; // hops counter-clockwise
                if fwd <= bwd {
                    let mut cur = a;
                    for _ in 0..fwd {
                        link_cw[cur] += bytes;
                        msgs_cw[cur] += 1;
                        cur = (cur + 1) % n;
                    }
                } else {
                    let mut cur = a;
                    for _ in 0..bwd {
                        link_ccw[cur] += bytes;
                        msgs_ccw[cur] += 1;
                        cur = (cur + n - 1) % n;
                    }
                }
            }
        }
        let mut loads = Vec::with_capacity(2 * n);
        for i in 0..n {
            loads.push(PortLoad { bytes: link_cw[i], msgs: msgs_cw[i] });
            loads.push(PortLoad { bytes: link_ccw[i], msgs: msgs_ccw[i] });
        }
        ExchangeProfile { loads }
    }

    /// PS: messages not touching the server are relayed (a→0, 0→b); the
    /// server's full-duplex port serializes all inbound and all outbound
    /// bytes independently; leaf ports can also bottleneck. The server entry
    /// folds the in/out directions into one load (`transfer_time` is
    /// monotone, so `max(t(in), t(out)) = t(max(in, out))` exactly); leaf
    /// ports pay no per-message latency, matching the original schedule.
    fn ps_profile(&self, msgs: &[u64]) -> ExchangeProfile {
        let n = self.nodes;
        let (mut srv_in, mut srv_out) = (0u64, 0u64);
        let (mut srv_in_msgs, mut srv_out_msgs) = (0u64, 0u64);
        let mut leaf_tx = vec![0u64; n];
        let mut leaf_rx = vec![0u64; n];
        for a in 0..n {
            for b in 0..n {
                let bytes = msgs[a * n + b];
                if bytes == 0 || a == b {
                    continue;
                }
                if a != 0 {
                    srv_in += bytes;
                    srv_in_msgs += 1;
                    leaf_tx[a] += bytes;
                }
                if b != 0 {
                    srv_out += bytes;
                    srv_out_msgs += 1;
                    leaf_rx[b] += bytes;
                }
            }
        }
        let mut loads = Vec::with_capacity(n + 1);
        loads.push(PortLoad {
            bytes: srv_in.max(srv_out),
            msgs: srv_in_msgs.max(srv_out_msgs),
        });
        for i in 0..n {
            loads.push(PortLoad { bytes: leaf_tx[i].max(leaf_rx[i]), msgs: 0 });
        }
        ExchangeProfile { loads }
    }
}

/// One serialized resource (a directed link or a node's TX/RX port) in a
/// boundary exchange: the payload bytes and distinct messages it carries.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PortLoad {
    pub bytes: u64,
    pub msgs: u64,
}

/// The bandwidth-independent load profile of one boundary exchange — the
/// output of [`Testbed::exchange_profile`], priced by
/// [`Testbed::price_exchange`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExchangeProfile {
    pub loads: Vec<PortLoad>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msgs(n: usize, entries: &[(usize, usize, u64)]) -> Vec<u64> {
        let mut m = vec![0u64; n * n];
        for &(a, b, bytes) in entries {
            m[a * n + b] = bytes;
        }
        m
    }

    #[test]
    fn bandwidth_units() {
        assert!((Bandwidth::gbps(5.0).transfer_time(625_000_000) - 1.0).abs() < 1e-9);
        assert!((Bandwidth::mbps(500.0).as_gbps() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_exchange_is_free() {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        assert_eq!(tb.exchange_time(&[0; 16]), 0.0);
    }

    #[test]
    fn mesh_parallelizes_disjoint_pairs() {
        let tb = Testbed::new(4, Topology::Mesh, Bandwidth::gbps(1.0));
        // 0->1 and 2->3 in parallel
        let m = msgs(4, &[(0, 1, 1_000_000), (2, 3, 1_000_000)]);
        let t = tb.exchange_time(&m);
        let single = tb.exchange_time(&msgs(4, &[(0, 1, 1_000_000)]));
        assert!((t - single).abs() < 1e-12);
    }

    #[test]
    fn ps_serializes_through_server() {
        let bw = Bandwidth::gbps(1.0);
        let mesh = Testbed::new(4, Topology::Mesh, bw);
        let ps = Testbed::new(4, Topology::Ps, bw);
        // leaf-to-leaf traffic: PS must relay both through node 0
        let m = msgs(4, &[(1, 2, 1_000_000), (3, 1, 1_000_000)]);
        assert!(ps.exchange_time(&m) > 1.9 * mesh.exchange_time(&m));
    }

    #[test]
    fn ring_neighbor_exchange_is_cheap() {
        let bw = Bandwidth::gbps(1.0);
        let ring = Testbed::new(4, Topology::Ring, bw);
        // neighbor halo pattern: i <-> i+1
        let m = msgs(
            4,
            &[
                (0, 1, 1_000),
                (1, 0, 1_000),
                (1, 2, 1_000),
                (2, 1, 1_000),
                (2, 3, 1_000),
                (3, 2, 1_000),
            ],
        );
        // each link carries exactly one message per direction
        let expect = bw.transfer_time(1_000) + ring.latency;
        assert!((ring.exchange_time(&m) - expect).abs() < 1e-9);
    }

    #[test]
    fn allgather_topology_ordering() {
        // All-to-all (OutC gather pattern): under per-port serialization the
        // 4-ring ties the mesh (3 MB through the busiest cw link vs 3 MB out
        // of one mesh port); the PS relay is strictly worse, and a larger
        // ring falls behind the mesh (longer shortest arcs).
        let bw = Bandwidth::gbps(1.0);
        let all2all = |n: usize| {
            let mut m = vec![0u64; n * n];
            for a in 0..n {
                for b in 0..n {
                    if a != b {
                        m[a * n + b] = 1_000_000;
                    }
                }
            }
            m
        };
        let m4 = all2all(4);
        let ring = Testbed::new(4, Topology::Ring, bw).exchange_time(&m4);
        let mesh = Testbed::new(4, Topology::Mesh, bw).exchange_time(&m4);
        let ps = Testbed::new(4, Topology::Ps, bw).exchange_time(&m4);
        assert!(ring >= mesh);
        assert!(ps > mesh);
        let m6 = all2all(6);
        let ring6 = Testbed::new(6, Topology::Ring, bw).exchange_time(&m6);
        let mesh6 = Testbed::new(6, Topology::Mesh, bw).exchange_time(&m6);
        assert!(ring6 > mesh6);
    }

    #[test]
    fn ring_uses_shortest_arc() {
        let bw = Bandwidth::gbps(1.0);
        let ring = Testbed::new(6, Topology::Ring, bw);
        // 0 -> 5 is one hop counter-clockwise, not five clockwise
        let m = msgs(6, &[(0, 5, 1_000_000)]);
        let expect = bw.transfer_time(1_000_000) + ring.latency;
        assert!((ring.exchange_time(&m) - expect).abs() < 1e-9);
    }

    #[test]
    fn device_profile_ordering() {
        let d = DeviceProfile::default();
        use crate::model::ConvType::*;
        // same flops: depthwise slower than standard slower than dense
        let f = 1e9;
        assert!(d.compute_time(f, Depthwise) > d.compute_time(f, Standard));
        assert!(d.compute_time(f, Standard) > d.compute_time(f, Dense));
        assert_eq!(d.compute_time(0.0, Standard), 0.0);
    }

    #[test]
    fn bandwidth_factor_scales_transfer_time() {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(2.0));
        let m = msgs(4, &[(0, 1, 10_000_000)]);
        let full = tb.exchange_time(&m);
        let half = tb.with_bandwidth_factor(0.5).exchange_time(&m);
        // halving bandwidth doubles the byte time (latency term unchanged)
        let bytes_full = full - tb.latency;
        let bytes_half = half - tb.latency;
        assert!((bytes_half - 2.0 * bytes_full).abs() < 1e-9);
    }

    #[test]
    fn subset_drops_dead_nodes_and_keeps_speeds() {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0))
            .with_speed(vec![1.0, 0.5, 2.0, 1.0]);
        let sub = tb.subset(&[true, false, true, true]);
        assert_eq!(sub.nodes, 3);
        assert_eq!(sub.speed, vec![1.0, 2.0, 1.0]);
        assert_eq!(sub.topology, tb.topology);
    }

    #[test]
    #[should_panic(expected = "no surviving nodes")]
    fn subset_rejects_empty_cluster() {
        let tb = Testbed::new(2, Topology::Ring, Bandwidth::gbps(1.0));
        tb.subset(&[false, false]);
    }

    #[test]
    fn exchange_profile_is_bandwidth_independent_and_reprices_exactly() {
        for topo in Topology::ALL {
            let tb = Testbed::new(4, topo, Bandwidth::gbps(2.0));
            let m = msgs(4, &[(0, 1, 1_000_000), (1, 2, 500), (3, 1, 123_456), (2, 0, 77)]);
            let profile = tb.exchange_profile(&m);
            // routing never depends on link speed
            let slow = tb.with_bandwidth_factor(0.25);
            assert_eq!(profile, slow.exchange_profile(&m));
            // pricing a cached profile equals re-running the schedule, to the bit
            assert_eq!(tb.price_exchange(&profile).to_bits(), tb.exchange_time(&m).to_bits());
            assert_eq!(
                slow.price_exchange(&profile).to_bits(),
                slow.exchange_time(&m).to_bits()
            );
        }
    }

    #[test]
    fn bandwidth_sweep_monotone() {
        let m = msgs(4, &[(0, 1, 10_000_000)]);
        let t5 = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0)).exchange_time(&m);
        let t1 = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0)).exchange_time(&m);
        let t05 = Testbed::new(4, Topology::Ring, Bandwidth::mbps(500.0)).exchange_time(&m);
        assert!(t5 < t1 && t1 < t05);
    }
}
