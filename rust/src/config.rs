//! Experiment configuration: the testbed/benchmark grids of the paper's §4,
//! plus the dynamic-conditions experiments of [`crate::elastic`], loadable
//! from JSON for custom sweeps.

use crate::cost::Objective;
use crate::elastic::{ConditionTrace, ElasticConfig, Profile};
use crate::net::{Bandwidth, Testbed, Topology};
use crate::util::json::Json;

/// The sweep grid for the figure benches.
#[derive(Debug, Clone)]
pub struct ExperimentGrid {
    pub models: Vec<String>,
    pub node_counts: Vec<usize>,
    pub topologies: Vec<Topology>,
    pub bandwidths_gbps: Vec<f64>,
}

impl ExperimentGrid {
    /// The paper's evaluation grid: 4 benchmarks; 4-node and 3-node
    /// testbeds; Ring and PS topologies (Mesh ≈ Ring per §4 footnote);
    /// 5 Gb/s, 1 Gb/s and 500 Mb/s SRIO-class bandwidths.
    pub fn paper() -> ExperimentGrid {
        ExperimentGrid {
            models: vec![
                "mobilenet".into(),
                "resnet18".into(),
                "resnet101".into(),
                "bert".into(),
            ],
            node_counts: vec![4, 3],
            topologies: vec![Topology::Ring, Topology::Ps],
            bandwidths_gbps: vec![5.0, 1.0, 0.5],
        }
    }

    /// A fast grid for CI / smoke runs (truncated models handled by caller).
    pub fn smoke() -> ExperimentGrid {
        ExperimentGrid {
            models: vec!["mobilenet".into()],
            node_counts: vec![4],
            topologies: vec![Topology::Ring],
            bandwidths_gbps: vec![1.0],
        }
    }

    pub fn testbeds(&self) -> Vec<Testbed> {
        let mut out = Vec::new();
        for &n in &self.node_counts {
            for &t in &self.topologies {
                for &bw in &self.bandwidths_gbps {
                    out.push(Testbed::new(n, t, Bandwidth::gbps(bw)));
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "models",
                Json::Arr(self.models.iter().map(|m| Json::Str(m.clone())).collect()),
            ),
            (
                "node_counts",
                Json::Arr(self.node_counts.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "topologies",
                Json::Arr(
                    self.topologies.iter().map(|t| Json::Str(t.name().to_string())).collect(),
                ),
            ),
            ("bandwidths_gbps", Json::num_arr(&self.bandwidths_gbps)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ExperimentGrid, String> {
        let strings = |key: &str| -> Result<Vec<String>, String> {
            Ok(v.req(key)?
                .as_arr()
                .ok_or_else(|| key.to_string())?
                .iter()
                .filter_map(|x| x.as_str().map(String::from))
                .collect())
        };
        let topologies = strings("topologies")?
            .iter()
            .map(|s| s.parse::<Topology>())
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ExperimentGrid {
            models: strings("models")?,
            node_counts: v
                .req("node_counts")?
                .as_arr()
                .ok_or("node_counts")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            topologies,
            bandwidths_gbps: v.req("bandwidths_gbps")?.as_f64_vec().ok_or("bandwidths")?,
        })
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<ExperimentGrid> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

/// A dynamic-conditions serving experiment: which condition profile to run,
/// for how long, and how the elastic controller is tuned.
#[derive(Debug, Clone)]
pub struct ElasticExperiment {
    /// Condition profile name (`stable`, `diurnal-drift`, `lossy-link`,
    /// `node-churn`).
    pub profile: String,
    pub seed: u64,
    /// Virtual-time horizon of the run, seconds.
    pub horizon: f64,
    pub degrade_threshold: f64,
    pub cache_capacity: usize,
}

impl Default for ElasticExperiment {
    fn default() -> Self {
        let ecfg = ElasticConfig::default();
        ElasticExperiment {
            profile: "diurnal-drift".into(),
            seed: 7,
            horizon: 120.0,
            degrade_threshold: ecfg.degrade_threshold,
            cache_capacity: ecfg.cache_capacity,
        }
    }
}

impl ElasticExperiment {
    /// The controller tuning described by this experiment.
    pub fn controller_config(&self) -> ElasticConfig {
        ElasticConfig {
            degrade_threshold: self.degrade_threshold,
            cache_capacity: self.cache_capacity,
            ..ElasticConfig::default()
        }
    }

    /// Build the condition trace for an `nodes`-device cluster.
    pub fn trace(&self, nodes: usize) -> Result<ConditionTrace, String> {
        Ok(match self.profile.parse::<Profile>()? {
            Profile::Stable => ConditionTrace::stable(nodes),
            Profile::DiurnalDrift => ConditionTrace::diurnal_drift(nodes, self.seed),
            Profile::LossyLink => ConditionTrace::lossy_link(nodes, self.seed),
            Profile::NodeChurn => ConditionTrace::node_churn(nodes, self.seed),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("profile", Json::Str(self.profile.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("horizon", Json::Num(self.horizon)),
            ("degrade_threshold", Json::Num(self.degrade_threshold)),
            ("cache_capacity", Json::Num(self.cache_capacity as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ElasticExperiment, String> {
        let num = |key: &str| v.req(key)?.as_f64().ok_or_else(|| key.to_string());
        Ok(ElasticExperiment {
            profile: v
                .req("profile")?
                .as_str()
                .ok_or_else(|| "profile".to_string())?
                .to_string(),
            seed: num("seed")? as u64,
            horizon: num("horizon")?,
            degrade_threshold: num("degrade_threshold")?,
            cache_capacity: num("cache_capacity")? as usize,
        })
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<ElasticExperiment> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

/// A pipelined-serving experiment: the cluster, the planning
/// [`Objective`], the pipeline depth and the request volume driving
/// `benches/pipeline_throughput.rs` and `examples/pipelined_serving.rs`.
#[derive(Debug, Clone)]
pub struct PipelineExperiment {
    /// Zoo model name.
    pub model: String,
    pub nodes: usize,
    pub topology: Topology,
    pub bandwidth_gbps: f64,
    /// Entry-queue budget of the block pipeline
    /// ([`crate::serve::ServeConfig::pipeline_depth`]).
    pub pipeline_depth: usize,
    /// What the planner minimizes for the served plan.
    pub objective: Objective,
    /// Requests to push through per measured run.
    pub requests: usize,
}

impl Default for PipelineExperiment {
    fn default() -> Self {
        PipelineExperiment {
            model: "edgenet".into(),
            nodes: 4,
            topology: Topology::Ring,
            bandwidth_gbps: 1.0,
            pipeline_depth: 4,
            objective: Objective::Throughput,
            requests: 32,
        }
    }
}

impl PipelineExperiment {
    pub fn testbed(&self) -> Testbed {
        Testbed::new(self.nodes, self.topology, Bandwidth::gbps(self.bandwidth_gbps))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("nodes", Json::Num(self.nodes as f64)),
            ("topology", Json::Str(self.topology.name().to_string())),
            ("bandwidth_gbps", Json::Num(self.bandwidth_gbps)),
            ("pipeline_depth", Json::Num(self.pipeline_depth as f64)),
            ("objective", Json::Str(self.objective.name().to_string())),
            ("requests", Json::Num(self.requests as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<PipelineExperiment, String> {
        let num = |key: &str| v.req(key)?.as_f64().ok_or_else(|| key.to_string());
        let model = v
            .req("model")?
            .as_str()
            .ok_or_else(|| "model".to_string())?
            .to_string();
        let topology = v
            .req("topology")?
            .as_str()
            .ok_or_else(|| "topology".to_string())?
            .parse::<Topology>()?;
        let objective = v
            .req("objective")?
            .as_str()
            .ok_or_else(|| "objective".to_string())?
            .parse::<Objective>()?;
        Ok(PipelineExperiment {
            model,
            nodes: num("nodes")? as usize,
            topology,
            bandwidth_gbps: num("bandwidth_gbps")?,
            pipeline_depth: num("pipeline_depth")? as usize,
            objective,
            requests: num("requests")? as usize,
        })
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<PipelineExperiment> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

/// A chaos-drill experiment: the fault-schedule shape and serving knobs
/// behind `benches/chaos_failover.rs`, `examples/chaos_drill.rs` and the
/// `chaos_e2e` CI job. Slot lengths are expressed as a multiple of the
/// healthy plan's per-item cost, so the same experiment file drives any
/// model/testbed at the same faults-per-batch density.
#[derive(Debug, Clone)]
pub struct ChaosExperiment {
    pub nodes: usize,
    pub seed: u64,
    /// Fault-schedule slots ([`crate::elastic::ChaosSchedule::generate`]).
    pub slots: usize,
    /// Slot length as a multiple of the healthy per-item virtual cost.
    pub slot_cost_factor: f64,
    /// Requests pushed through per run.
    pub requests: usize,
    /// Pipeline depth of the serving path (`<= 1` = lockstep).
    pub pipeline_depth: usize,
}

impl Default for ChaosExperiment {
    fn default() -> Self {
        ChaosExperiment {
            nodes: 4,
            seed: 11,
            slots: 8,
            slot_cost_factor: 2.0,
            requests: 24,
            pipeline_depth: 3,
        }
    }
}

impl ChaosExperiment {
    /// Generate the deterministic schedule, given the healthy plan's
    /// per-item virtual cost on the target testbed.
    pub fn schedule(&self, healthy_cost: f64) -> crate::elastic::ChaosSchedule {
        crate::elastic::ChaosSchedule::generate(
            self.nodes,
            self.seed,
            self.slots,
            self.slot_cost_factor * healthy_cost,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::Num(self.nodes as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("slot_cost_factor", Json::Num(self.slot_cost_factor)),
            ("requests", Json::Num(self.requests as f64)),
            ("pipeline_depth", Json::Num(self.pipeline_depth as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ChaosExperiment, String> {
        let num = |key: &str| v.req(key)?.as_f64().ok_or_else(|| key.to_string());
        let exp = ChaosExperiment {
            nodes: num("nodes")? as usize,
            seed: num("seed")? as u64,
            slots: num("slots")? as usize,
            slot_cost_factor: num("slot_cost_factor")?,
            requests: num("requests")? as usize,
            pipeline_depth: num("pipeline_depth")? as usize,
        };
        if exp.nodes < 2 {
            return Err("chaos needs at least two nodes".into());
        }
        if exp.slots < 6 {
            return Err("too few slots to guarantee a leader strike".into());
        }
        if !(exp.slot_cost_factor > 0.0 && exp.slot_cost_factor.is_finite()) {
            return Err("slot_cost_factor must be a positive finite number".into());
        }
        Ok(exp)
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<ChaosExperiment> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

/// A telemetry-driven forecasting experiment: the condition world, probe
/// cadence, forecast horizon and drive shape behind
/// `benches/forecast_warmup.rs`, `examples/forecast_serving.rs` and the
/// `forecast_e2e` CI job.
#[derive(Debug, Clone)]
pub struct ForecastExperiment {
    /// Condition-world profile name (`stable`, `diurnal-drift`,
    /// `lossy-link`, `node-churn`).
    pub profile: String,
    pub seed: u64,
    /// Virtual-time horizon the experiment drives, seconds.
    pub horizon: f64,
    /// Virtual seconds between consulted batch boundaries.
    pub boundary_dt: f64,
    /// Forecast horizon, in batch boundaries
    /// ([`crate::telemetry::ForecastConfig::horizon_boundaries`]).
    pub horizon_boundaries: usize,
    /// Active-probe spacing, virtual seconds
    /// ([`crate::telemetry::TelemetryConfig::probe_interval`]).
    pub probe_interval: f64,
    /// Active-probe payload bytes.
    pub probe_bytes: u64,
    /// Plan-cache capacity (forecast pre-warming holds more cells warm
    /// than the reactive default needs).
    pub cache_capacity: usize,
}

impl Default for ForecastExperiment {
    fn default() -> Self {
        let tcfg = crate::telemetry::TelemetryConfig::default();
        ForecastExperiment {
            profile: "diurnal-drift".into(),
            seed: 7,
            horizon: 60.0,
            boundary_dt: 0.5,
            horizon_boundaries: crate::telemetry::ForecastConfig::default().horizon_boundaries,
            probe_interval: tcfg.probe_interval,
            probe_bytes: tcfg.probe_bytes,
            cache_capacity: 64,
        }
    }
}

impl ForecastExperiment {
    /// Build the hidden condition world for an `nodes`-device cluster.
    pub fn world(&self, nodes: usize) -> Result<ConditionTrace, String> {
        Ok(match self.profile.parse::<Profile>()? {
            Profile::Stable => ConditionTrace::stable(nodes),
            Profile::DiurnalDrift => ConditionTrace::diurnal_drift(nodes, self.seed),
            Profile::LossyLink => ConditionTrace::lossy_link(nodes, self.seed),
            Profile::NodeChurn => ConditionTrace::node_churn(nodes, self.seed),
        })
    }

    /// The ingestion knobs this experiment describes.
    pub fn telemetry_config(&self) -> crate::telemetry::TelemetryConfig {
        crate::telemetry::TelemetryConfig {
            probe_interval: self.probe_interval,
            probe_bytes: self.probe_bytes,
            ..crate::telemetry::TelemetryConfig::default()
        }
    }

    /// The forecasting knobs this experiment describes.
    pub fn forecast_config(&self) -> crate::telemetry::ForecastConfig {
        crate::telemetry::ForecastConfig {
            horizon_boundaries: self.horizon_boundaries,
            ..crate::telemetry::ForecastConfig::default()
        }
    }

    /// The elastic-controller tuning with forecasting enabled.
    pub fn elastic_config(&self) -> ElasticConfig {
        ElasticConfig {
            cache_capacity: self.cache_capacity,
            forecast: Some(self.forecast_config()),
            ..ElasticConfig::default()
        }
    }

    /// Number of consulted boundaries the experiment drives.
    pub fn boundaries(&self) -> usize {
        (self.horizon / self.boundary_dt).floor() as usize + 1
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("profile", Json::Str(self.profile.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("horizon", Json::Num(self.horizon)),
            ("boundary_dt", Json::Num(self.boundary_dt)),
            ("horizon_boundaries", Json::Num(self.horizon_boundaries as f64)),
            ("probe_interval", Json::Num(self.probe_interval)),
            ("probe_bytes", Json::Num(self.probe_bytes as f64)),
            ("cache_capacity", Json::Num(self.cache_capacity as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ForecastExperiment, String> {
        let num = |key: &str| v.req(key)?.as_f64().ok_or_else(|| key.to_string());
        let exp = ForecastExperiment {
            profile: v
                .req("profile")?
                .as_str()
                .ok_or_else(|| "profile".to_string())?
                .to_string(),
            seed: num("seed")? as u64,
            horizon: num("horizon")?,
            boundary_dt: num("boundary_dt")?,
            horizon_boundaries: num("horizon_boundaries")? as usize,
            probe_interval: num("probe_interval")?,
            probe_bytes: num("probe_bytes")? as u64,
            cache_capacity: num("cache_capacity")? as usize,
        };
        if !(exp.boundary_dt > 0.0 && exp.boundary_dt.is_finite()) {
            return Err("boundary_dt must be a positive finite number".into());
        }
        if exp.horizon_boundaries == 0 {
            return Err("horizon_boundaries must be at least 1".into());
        }
        if !(exp.probe_interval > 0.0 && exp.probe_interval.is_finite()) {
            return Err("probe_interval must be a positive finite number".into());
        }
        if exp.probe_bytes == 0 {
            return Err("probe_bytes must be >= 1: a zero-byte probe measures nothing".into());
        }
        Ok(exp)
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<ForecastExperiment> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

/// A wire-transport experiment: which fabric carries the inter-node
/// traffic (`sim` — in-process channels, the deterministic CI default, or
/// `tcp` — one daemon process per node over real sockets), where the
/// registry lives, and the lease/heartbeat/deadline timing that governs
/// failure detection. Drives `benches/transport_overhead.rs` and
/// `examples/distributed_serving.rs`.
#[derive(Debug, Clone)]
pub struct TransportExperiment {
    /// `"sim"` or `"tcp"`.
    pub mode: String,
    /// Registry address (`tcp:HOST:PORT` or `unix:/path`); port 0 binds
    /// ephemerally when the experiment hosts its own registry.
    pub registry: String,
    pub nodes: usize,
    /// Registry lease TTL, ms — expiry is the liveness signal.
    pub ttl_ms: u64,
    /// Data-plane heartbeat interval, ms.
    pub heartbeat_ms: u64,
    /// Silence after which a peer is declared dead, ms.
    pub heartbeat_timeout_ms: u64,
    /// Mesh dial deadline at plan install, ms.
    pub connect_timeout_ms: u64,
    /// Coordinator bound on one inference round trip, ms.
    pub infer_deadline_ms: u64,
    /// Zoo model name.
    pub model: String,
    pub seed: u64,
    /// Requests pushed through per measured run.
    pub requests: usize,
}

impl Default for TransportExperiment {
    fn default() -> Self {
        TransportExperiment {
            mode: "tcp".into(),
            registry: "tcp:127.0.0.1:0".into(),
            nodes: 3,
            ttl_ms: 1000,
            heartbeat_ms: 100,
            heartbeat_timeout_ms: 1200,
            connect_timeout_ms: 10_000,
            infer_deadline_ms: 60_000,
            model: "edgenet".into(),
            seed: 5,
            requests: 16,
        }
    }
}

impl TransportExperiment {
    pub fn is_tcp(&self) -> bool {
        self.mode == "tcp"
    }

    /// The socket-fabric timing this experiment describes.
    pub fn tcp_opts(&self) -> crate::transport::tcp::TcpOpts {
        crate::transport::tcp::TcpOpts {
            connect_deadline: std::time::Duration::from_millis(self.connect_timeout_ms),
            heartbeat_interval: std::time::Duration::from_millis(self.heartbeat_ms),
            heartbeat_timeout: std::time::Duration::from_millis(self.heartbeat_timeout_ms),
            ..crate::transport::tcp::TcpOpts::default()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("registry", Json::Str(self.registry.clone())),
            ("nodes", Json::Num(self.nodes as f64)),
            ("ttl_ms", Json::Num(self.ttl_ms as f64)),
            ("heartbeat_ms", Json::Num(self.heartbeat_ms as f64)),
            ("heartbeat_timeout_ms", Json::Num(self.heartbeat_timeout_ms as f64)),
            ("connect_timeout_ms", Json::Num(self.connect_timeout_ms as f64)),
            ("infer_deadline_ms", Json::Num(self.infer_deadline_ms as f64)),
            ("model", Json::Str(self.model.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("requests", Json::Num(self.requests as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TransportExperiment, String> {
        let num = |key: &str| v.req(key)?.as_f64().ok_or_else(|| key.to_string());
        let s = |key: &str| -> Result<String, String> {
            Ok(v.req(key)?.as_str().ok_or_else(|| key.to_string())?.to_string())
        };
        let exp = TransportExperiment {
            mode: s("mode")?,
            registry: s("registry")?,
            nodes: num("nodes")? as usize,
            ttl_ms: num("ttl_ms")? as u64,
            heartbeat_ms: num("heartbeat_ms")? as u64,
            heartbeat_timeout_ms: num("heartbeat_timeout_ms")? as u64,
            connect_timeout_ms: num("connect_timeout_ms")? as u64,
            infer_deadline_ms: num("infer_deadline_ms")? as u64,
            model: s("model")?,
            seed: num("seed")? as u64,
            requests: num("requests")? as usize,
        };
        if exp.mode != "sim" && exp.mode != "tcp" {
            return Err(format!("mode must be \"sim\" or \"tcp\", got {:?}", exp.mode));
        }
        if exp.nodes == 0 {
            return Err("nodes must be at least 1".into());
        }
        if exp.ttl_ms == 0 {
            return Err("ttl_ms must be positive: a zero-length lease is never live".into());
        }
        if exp.heartbeat_timeout_ms <= exp.heartbeat_ms {
            return Err(
                "heartbeat_timeout_ms must exceed heartbeat_ms, or every peer looks dead".into(),
            );
        }
        if exp.requests == 0 {
            return Err("requests must be at least 1".into());
        }
        Ok(exp)
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<TransportExperiment> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

/// A wire-fault experiment: which fabric to disrupt (`sim` — in-process
/// channels, or `tcp` — one daemon per node over real sockets), the
/// seeded [`crate::transport::fault::FaultSchedule`] shape, and the
/// replay budget that bounds end-to-end recovery. Drives
/// `examples/fault_drill.rs` and the `fault_e2e` CI job.
#[derive(Debug, Clone)]
pub struct FaultExperiment {
    /// `"sim"` or `"tcp"`.
    pub fabric: String,
    pub nodes: usize,
    pub seed: u64,
    /// Fault-schedule windows
    /// ([`crate::transport::fault::FaultSchedule::generate`]).
    pub windows: usize,
    /// Send operations per window.
    pub window_ops: u64,
    /// Requests pushed through per run.
    pub requests: u64,
    /// Zoo model name.
    pub model: String,
    /// Re-execution budget per request
    /// ([`crate::serve::ServeConfig::replay_budget`]).
    pub replay_budget: u32,
}

impl Default for FaultExperiment {
    fn default() -> Self {
        FaultExperiment {
            fabric: "sim".into(),
            nodes: 3,
            seed: 11,
            windows: 6,
            window_ops: 64,
            requests: 12,
            model: "edgenet".into(),
            replay_budget: 6,
        }
    }
}

impl FaultExperiment {
    pub fn is_tcp(&self) -> bool {
        self.fabric == "tcp"
    }

    /// Generate the deterministic wire-fault schedule this experiment
    /// describes.
    pub fn schedule(&self) -> crate::transport::fault::FaultSchedule {
        crate::transport::fault::FaultSchedule::generate(
            self.nodes,
            self.seed,
            self.windows,
            self.window_ops,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fabric", Json::Str(self.fabric.clone())),
            ("nodes", Json::Num(self.nodes as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("windows", Json::Num(self.windows as f64)),
            ("window_ops", Json::Num(self.window_ops as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("model", Json::Str(self.model.clone())),
            ("replay_budget", Json::Num(self.replay_budget as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FaultExperiment, String> {
        let num = |key: &str| v.req(key)?.as_f64().ok_or_else(|| key.to_string());
        let s = |key: &str| -> Result<String, String> {
            Ok(v.req(key)?.as_str().ok_or_else(|| key.to_string())?.to_string())
        };
        let exp = FaultExperiment {
            fabric: s("fabric")?,
            nodes: num("nodes")? as usize,
            seed: num("seed")? as u64,
            windows: num("windows")? as usize,
            window_ops: num("window_ops")? as u64,
            requests: num("requests")? as u64,
            model: s("model")?,
            replay_budget: num("replay_budget")? as u32,
        };
        if exp.fabric != "sim" && exp.fabric != "tcp" {
            return Err(format!("fabric must be \"sim\" or \"tcp\", got {:?}", exp.fabric));
        }
        if exp.nodes < 2 {
            return Err("wire faults need at least two nodes".into());
        }
        if exp.windows == 0 {
            return Err("windows must be at least 1".into());
        }
        if exp.window_ops < 8 {
            return Err("window_ops must be at least 8: shorter windows degenerate".into());
        }
        if exp.requests == 0 {
            return Err("requests must be at least 1".into());
        }
        Ok(exp)
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<FaultExperiment> {
        let v = Json::load(path)?;
        Self::from_json(&v).map_err(std::io::Error::other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_shape() {
        let g = ExperimentGrid::paper();
        assert_eq!(g.models.len(), 4);
        assert_eq!(g.testbeds().len(), 2 * 2 * 3);
    }

    #[test]
    fn json_roundtrip() {
        let g = ExperimentGrid::paper();
        let j = g.to_json();
        let g2 = ExperimentGrid::from_json(&j).unwrap();
        assert_eq!(g.models, g2.models);
        assert_eq!(g.node_counts, g2.node_counts);
        assert_eq!(g.topologies, g2.topologies);
        assert_eq!(g.bandwidths_gbps, g2.bandwidths_gbps);
    }

    #[test]
    fn load_from_file() {
        let dir = crate::util::tmp::TempDir::new("grid");
        let p = dir.path().join("grid.json");
        ExperimentGrid::smoke().to_json().save(&p).unwrap();
        let g = ExperimentGrid::load(&p).unwrap();
        assert_eq!(g.models, vec!["mobilenet"]);
    }

    #[test]
    fn pipeline_experiment_roundtrip() {
        let e = PipelineExperiment {
            objective: Objective::Latency,
            pipeline_depth: 7,
            ..Default::default()
        };
        let e2 = PipelineExperiment::from_json(&e.to_json()).unwrap();
        assert_eq!(e2.model, e.model);
        assert_eq!(e2.objective, Objective::Latency);
        assert_eq!(e2.pipeline_depth, 7);
        assert_eq!(e2.testbed().nodes, 4);
        // bad objective strings are rejected
        let mut j = e.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("objective".into(), Json::Str("speed".into()));
        }
        assert!(PipelineExperiment::from_json(&j).is_err());
    }

    #[test]
    fn elastic_experiment_roundtrip_and_trace() {
        let e = ElasticExperiment::default();
        let e2 = ElasticExperiment::from_json(&e.to_json()).unwrap();
        assert_eq!(e.profile, e2.profile);
        assert_eq!(e.seed, e2.seed);
        assert_eq!(e.cache_capacity, e2.cache_capacity);
        let trace = e2.trace(4).unwrap();
        assert_eq!(trace.nodes, 4);
        assert_eq!(trace.profile, Profile::DiurnalDrift);
        assert!(ElasticExperiment { profile: "bogus".into(), ..e }.trace(4).is_err());
    }

    #[test]
    fn forecast_experiment_roundtrip_and_configs() {
        let e = ForecastExperiment { seed: 13, horizon_boundaries: 6, ..Default::default() };
        let e2 = ForecastExperiment::from_json(&e.to_json()).unwrap();
        assert_eq!(e2.profile, "diurnal-drift");
        assert_eq!((e2.seed, e2.horizon_boundaries), (13, 6));
        assert_eq!(e2.boundary_dt, e.boundary_dt);
        assert_eq!(e2.probe_bytes, e.probe_bytes);
        let world = e2.world(4).unwrap();
        assert_eq!((world.nodes, world.profile), (4, Profile::DiurnalDrift));
        let ecfg = e2.elastic_config();
        assert_eq!(ecfg.cache_capacity, e2.cache_capacity);
        assert_eq!(
            ecfg.forecast.expect("forecasting must be on").horizon_boundaries,
            6
        );
        assert_eq!(e2.telemetry_config().probe_interval, e2.probe_interval);
        assert_eq!(e2.boundaries(), 121);
        // degenerate shapes are rejected
        let mut j = e.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("boundary_dt".into(), Json::Num(0.0));
        }
        assert!(ForecastExperiment::from_json(&j).is_err());
        let mut j = e.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("horizon_boundaries".into(), Json::Num(0.0));
        }
        assert!(ForecastExperiment::from_json(&j).is_err());
        let mut j = e.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("probe_bytes".into(), Json::Num(0.0));
        }
        assert!(
            ForecastExperiment::from_json(&j).is_err(),
            "a zero-byte probe config must be rejected at load time"
        );
        assert!(ForecastExperiment { profile: "bogus".into(), ..e }.world(4).is_err());
    }

    #[test]
    fn transport_experiment_roundtrip_and_validation() {
        let e = TransportExperiment { nodes: 4, seed: 9, ..Default::default() };
        let e2 = TransportExperiment::from_json(&e.to_json()).unwrap();
        assert_eq!((e2.nodes, e2.seed), (4, 9));
        assert_eq!(e2.mode, "tcp");
        assert!(e2.is_tcp());
        assert_eq!(e2.registry, e.registry);
        assert_eq!(e2.model, "edgenet");
        assert_eq!(e2.requests, e.requests);
        let opts = e2.tcp_opts();
        assert_eq!(opts.heartbeat_interval.as_millis() as u64, e.heartbeat_ms);
        assert_eq!(opts.heartbeat_timeout.as_millis() as u64, e.heartbeat_timeout_ms);
        assert_eq!(opts.connect_deadline.as_millis() as u64, e.connect_timeout_ms);
        // file round trip
        let dir = crate::util::tmp::TempDir::new("transport");
        let p = dir.path().join("transport.json");
        e.to_json().save(&p).unwrap();
        assert_eq!(TransportExperiment::load(&p).unwrap().nodes, 4);
        // degenerate shapes are rejected
        let mutate = |key: &str, val: Json| {
            let mut j = e.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert(key.into(), val);
            }
            TransportExperiment::from_json(&j)
        };
        assert!(mutate("mode", Json::Str("udp".into())).is_err());
        assert!(mutate("nodes", Json::Num(0.0)).is_err());
        assert!(mutate("ttl_ms", Json::Num(0.0)).is_err());
        assert!(
            mutate("heartbeat_timeout_ms", Json::Num(50.0)).is_err(),
            "timeout <= interval must be rejected: every peer would look dead"
        );
        assert!(mutate("requests", Json::Num(0.0)).is_err());
        assert!(mutate("mode", Json::Str("sim".into())).is_ok(), "sim mode is valid");
    }

    #[test]
    fn fault_experiment_roundtrip_and_schedule() {
        let e = FaultExperiment { seed: 23, windows: 4, ..Default::default() };
        let e2 = FaultExperiment::from_json(&e.to_json()).unwrap();
        assert_eq!((e2.nodes, e2.seed, e2.windows), (3, 23, 4));
        assert_eq!(e2.fabric, "sim");
        assert!(!e2.is_tcp());
        assert_eq!(e2.window_ops, e.window_ops);
        assert_eq!(e2.replay_budget, e.replay_budget);
        assert_eq!(e2.model, "edgenet");
        let s = e2.schedule();
        assert_eq!((s.nodes, s.seed, s.window_ops), (3, 23, 64));
        assert!(!s.is_empty() && s.len() <= 4, "at most one fault per window");
        // file round trip
        let dir = crate::util::tmp::TempDir::new("fault");
        let p = dir.path().join("fault.json");
        e.to_json().save(&p).unwrap();
        assert_eq!(FaultExperiment::load(&p).unwrap().seed, 23);
        // degenerate shapes are rejected
        let mutate = |key: &str, val: Json| {
            let mut j = e.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert(key.into(), val);
            }
            FaultExperiment::from_json(&j)
        };
        assert!(mutate("fabric", Json::Str("udp".into())).is_err());
        assert!(mutate("nodes", Json::Num(1.0)).is_err());
        assert!(mutate("windows", Json::Num(0.0)).is_err());
        assert!(mutate("window_ops", Json::Num(4.0)).is_err());
        assert!(mutate("requests", Json::Num(0.0)).is_err());
        assert!(mutate("fabric", Json::Str("tcp".into())).is_ok(), "tcp fabric is valid");
    }

    #[test]
    fn chaos_experiment_roundtrip_and_schedule() {
        let e = ChaosExperiment { seed: 23, slots: 9, ..Default::default() };
        let e2 = ChaosExperiment::from_json(&e.to_json()).unwrap();
        assert_eq!((e2.nodes, e2.seed, e2.slots), (4, 23, 9));
        assert_eq!(e2.pipeline_depth, e.pipeline_depth);
        let s = e2.schedule(0.01);
        assert_eq!(s.nodes, 4);
        assert!((s.slot - 0.02).abs() < 1e-15);
        assert!(s.kills_leader(), "experiment schedules must strike the leader");
        // degenerate shapes are rejected
        let mut j = e.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("slots".into(), Json::Num(2.0));
        }
        assert!(ChaosExperiment::from_json(&j).is_err());
        let mut j = e.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("slot_cost_factor".into(), Json::Num(0.0));
        }
        assert!(ChaosExperiment::from_json(&j).is_err(), "zero slot length must be rejected");
    }
}
