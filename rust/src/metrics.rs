//! Serving metrics: latency summaries and throughput accounting.

use std::time::Duration;

/// Latency summary over a set of samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
    pub max: Duration,
}

/// Summarize a sample set (empty input → all-zero summary).
pub fn summarize(samples: &[Duration]) -> Summary {
    if samples.is_empty() {
        let z = Duration::ZERO;
        return Summary { count: 0, mean: z, p50: z, p90: z, p99: z, max: z };
    }
    let mut sorted = samples.to_vec();
    sorted.sort();
    let pct = |p: f64| {
        let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[i]
    };
    let total: Duration = sorted.iter().sum();
    Summary {
        count: sorted.len(),
        mean: total / sorted.len() as u32,
        p50: pct(0.50),
        p90: pct(0.90),
        p99: pct(0.99),
        max: *sorted.last().unwrap(),
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        use crate::util::bench::fmt_dur;
        write!(
            f,
            "n={} mean={} p50={} p90={} p99={} max={}",
            self.count,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p90),
            fmt_dur(self.p99),
            fmt_dur(self.max)
        )
    }
}

/// Adaptation counters for the elastic serving path ([`crate::elastic`]):
/// how often conditions were checked, how often the active plan was found
/// degraded, and how the replanner's plan cache performed.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct AdaptationMetrics {
    /// Condition checks performed (one per batch boundary).
    pub checks: u64,
    /// Checks where the active plan's predicted cost exceeded the
    /// degradation threshold.
    pub degraded_checks: u64,
    /// Planner invocations (plan-cache misses that ran DPP).
    pub replans: u64,
    /// Times the active plan was replaced by a structurally different one.
    pub plan_swaps: u64,
    /// Swaps forced by a node joining or leaving the cluster.
    pub failovers: u64,
    /// Failovers that moved leadership: the lowest surviving rank changed,
    /// so scatter/ingress and gather re-homed onto a different device
    /// (includes original rank 0 reclaiming leadership on rejoin).
    pub leader_handoffs: u64,
    /// Warm plans served straight from the plan cache.
    pub cache_hits: u64,
    /// Plan-cache misses.
    pub cache_misses: u64,
    /// n−1 failover plans pre-computed speculatively by the background
    /// planner while the cluster was healthy.
    pub speculative_plans: u64,
    /// Plan-cache hits served by a speculatively pre-computed plan (a
    /// node-loss failover that never waited on a search).
    pub speculative_hits: u64,
    /// DPP searches executed inline on the router thread at a batch
    /// boundary. Always zero on the background-replanner path; non-zero
    /// only for the synchronous [`crate::elastic::ElasticController`].
    pub inline_replans: u64,
    /// Forecast pre-warm requests handed to the background planner (one per
    /// projected condition cell the forecaster flagged as upcoming).
    pub forecasts: u64,
    /// Condition cells planned *ahead of time* from a forecast (cache fills
    /// that never blocked anything).
    pub forecast_plans: u64,
    /// Serving-path replans answered by a forecast-warmed cache cell — the
    /// regime shift arrived and its plan was already there.
    pub forecast_hits: u64,
    /// Serving-path cache misses on *same-node-set* shifts while
    /// forecasting was active: drift the forecaster could have predicted
    /// but didn't pre-warm. Node-set misses are excluded — liveness is
    /// carried, never extrapolated, so node deaths are not forecastable
    /// events and must not deflate the hit rate.
    pub forecast_misses: u64,
    /// Matured forecasts compared against the conditions that actually
    /// arrived at their target time.
    pub forecast_evals: u64,
    /// Cumulative horizon error over those comparisons, in quantized
    /// bandwidth buckets: `Σ |predicted_bucket − actual_bucket|`. Divide by
    /// `forecast_evals` for the mean bucket error.
    pub forecast_bucket_err: u64,
    /// Boundaries served on a plan whose replacement was requested more
    /// than [`crate::elastic::ElasticConfig::stale_after_checks`] boundaries
    /// ago and still hasn't been published — the canary for a wedged
    /// planner thread. Zero in healthy operation.
    pub stale_plan_boundaries: u64,
}

/// Shared hit-rate formula (0.0 before any lookup) — used by both
/// [`AdaptationMetrics`] and [`crate::elastic::PlanCache`] so the two views
/// cannot drift.
pub fn hit_ratio(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

impl AdaptationMetrics {
    /// Fraction of plan lookups answered from the cache (0.0 when none).
    pub fn cache_hit_rate(&self) -> f64 {
        hit_ratio(self.cache_hits, self.cache_misses)
    }

    /// Of the serving-path replans that happened while forecasting was
    /// active, the fraction the forecaster had pre-warmed (0.0 when none).
    pub fn forecast_hit_rate(&self) -> f64 {
        hit_ratio(self.forecast_hits, self.forecast_misses)
    }

    /// Mean horizon error of matured forecasts, in quantized bandwidth
    /// buckets (0.0 before any forecast matured).
    pub fn forecast_mean_bucket_err(&self) -> f64 {
        if self.forecast_evals == 0 {
            0.0
        } else {
            self.forecast_bucket_err as f64 / self.forecast_evals as f64
        }
    }
}

impl std::fmt::Display for AdaptationMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "checks={} degraded={} replans={} swaps={} failovers={} handoffs={} \
             cache={}/{} ({:.0}% hit) spec={}p/{}h fc={}a/{}p/{}h/{}m stale={} inline={}",
            self.checks,
            self.degraded_checks,
            self.replans,
            self.plan_swaps,
            self.failovers,
            self.leader_handoffs,
            self.cache_hits,
            self.cache_hits + self.cache_misses,
            self.cache_hit_rate() * 100.0,
            self.speculative_plans,
            self.speculative_hits,
            self.forecasts,
            self.forecast_plans,
            self.forecast_hits,
            self.forecast_misses,
            self.stale_plan_boundaries,
            self.inline_replans
        )
    }
}

/// Pipelined-serving counters for [`crate::serve::RouterStats`]: per-stage
/// occupancy of the block pipeline plus drain-and-flush accounting. When a
/// plan swap rebuilds the pipeline mid-run, the occupancy snapshot comes
/// from the *dominant* generation (the one that served the most items) —
/// per-stage shapes differ across plans, so fractions cannot be merged.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineSummary {
    /// Stages (fused blocks) of the dominant generation's pipeline.
    pub stages: usize,
    /// Total items served across all generations.
    pub items: u64,
    /// Busy fraction per stage of the dominant generation (0..=1).
    pub occupancy: Vec<f64>,
    /// Busiest stage index of the dominant generation.
    pub bottleneck_stage: usize,
    /// Pipeline generations served (1 + drain-and-flush plan swaps).
    pub generations: u64,
    /// Items served by the dominant generation (the one the occupancy
    /// snapshot describes).
    pub items_dominant: u64,
    /// Tensor-buffer requests served by arena recycling, summed across all
    /// stages and generations — the steady-state allocation story.
    pub buf_reuses: u64,
    /// Tensor-buffer requests that provisioned a fresh buffer.
    pub buf_allocs: u64,
}

impl PipelineSummary {
    /// Fold one drained generation into the summary.
    pub fn absorb(&mut self, stages: usize, items: u64, occupancy: Vec<f64>, bottleneck: usize) {
        self.generations += 1;
        let dominant = self.generations == 1 || items >= self.items_dominant;
        self.items += items;
        if dominant {
            self.items_dominant = items;
            self.stages = stages;
            self.occupancy = occupancy;
            self.bottleneck_stage = bottleneck;
        }
    }
}

impl std::fmt::Display for PipelineSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let occ: Vec<String> =
            self.occupancy.iter().map(|o| format!("{:.0}%", o * 100.0)).collect();
        write!(
            f,
            "stages={} items={} generations={} bottleneck=s{} occupancy=[{}] buf={}r/{}a",
            self.stages,
            self.items,
            self.generations,
            self.bottleneck_stage,
            occ.join(" "),
            self.buf_reuses,
            self.buf_allocs
        )
    }
}

/// Unified named-counter snapshot: one flat, sorted `name → value` map that
/// every subsystem's counters fold into ([`crate::serve::RouterStats`],
/// [`AdaptationMetrics`], [`PipelineSummary`], per-node resource deltas from
/// trace dumps). A registry is a *snapshot*, not a live sink — build one at
/// a reporting boundary (server shutdown, `flexpie-ctl metrics`), dump it,
/// drop it. Keys are dotted paths (`router.requests`,
/// `router.shed.queue_full`, `node3.rss_bytes`) so grep and diff stay easy.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    counters: std::collections::BTreeMap<String, u64>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Set (or overwrite) a counter.
    pub fn set(&mut self, name: &str, value: u64) {
        self.counters.insert(name.to_string(), value);
    }

    /// Add to a counter, creating it at zero first.
    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Look a counter up (`None` = never set — distinct from zero).
    pub fn get(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    pub fn len(&self) -> usize {
        self.counters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// All counters in sorted-name order.
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Flat JSON object, keys in sorted order (names are code-controlled
    /// dotted identifiers, so no escaping is ever needed).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{k}\":{v}"));
        }
        s.push('}');
        s
    }
}

impl std::fmt::Display for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (k, v) in self.counters.iter() {
            writeln!(f, "{k} {v}")?;
        }
        Ok(())
    }
}

/// Simple throughput window: items per second of wall-clock.
#[derive(Debug)]
pub struct Throughput {
    started: std::time::Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput { started: std::time::Instant::now(), items: 0 }
    }

    pub fn record(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn items(&self) -> u64 {
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        let s = summarize(&samples);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, Duration::from_millis(51)); // round((100-1)*0.5)=50 → sorted[50]=51ms
        assert_eq!(s.p99, Duration::from_millis(99));
        assert_eq!(s.max, Duration::from_millis(100));
    }

    #[test]
    fn empty_summary() {
        let s = summarize(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.max, Duration::ZERO);
    }

    #[test]
    fn adaptation_hit_rate() {
        let mut m = AdaptationMetrics::default();
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.cache_hits = 3;
        m.cache_misses = 1;
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.to_string();
        assert!(s.contains("cache=3/4"), "{s}");
    }

    #[test]
    fn forecast_rates() {
        let mut m = AdaptationMetrics::default();
        assert_eq!(m.forecast_hit_rate(), 0.0);
        assert_eq!(m.forecast_mean_bucket_err(), 0.0);
        m.forecast_hits = 3;
        m.forecast_misses = 1;
        m.forecast_evals = 4;
        m.forecast_bucket_err = 2;
        assert!((m.forecast_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.forecast_mean_bucket_err() - 0.5).abs() < 1e-12);
        let s = m.to_string();
        assert!(s.contains("fc=0a/0p/3h/1m"), "{s}");
        assert!(s.contains("stale=0"), "{s}");
    }

    #[test]
    fn pipeline_summary_tracks_dominant_generation() {
        let mut p = PipelineSummary::default();
        p.absorb(3, 10, vec![0.5, 0.9, 0.2], 1);
        assert_eq!((p.generations, p.items, p.stages), (1, 10, 3));
        assert_eq!(p.bottleneck_stage, 1);
        // a smaller generation must not displace the occupancy snapshot
        p.absorb(2, 4, vec![0.1, 0.1], 0);
        assert_eq!((p.generations, p.items, p.stages), (2, 14, 3));
        assert_eq!(p.occupancy.len(), 3);
        // a larger one does
        p.absorb(4, 20, vec![0.3; 4], 2);
        assert_eq!((p.generations, p.items, p.stages), (3, 34, 4));
        assert_eq!(p.bottleneck_stage, 2);
        let s = p.to_string();
        assert!(s.contains("generations=3"), "{s}");
    }

    #[test]
    fn registry_is_sorted_and_json_round_readable() {
        let mut r = Registry::new();
        r.set("router.requests", 42);
        r.set("node3.rss_bytes", 1024);
        r.add("router.shed.queue_full", 2);
        r.add("router.shed.queue_full", 3);
        assert_eq!(r.get("router.shed.queue_full"), Some(5));
        assert_eq!(r.get("missing"), None);
        assert_eq!(r.len(), 3);
        let names: Vec<&str> = r.counters().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["node3.rss_bytes", "router.requests", "router.shed.queue_full"]);
        assert_eq!(
            r.to_json(),
            "{\"node3.rss_bytes\":1024,\"router.requests\":42,\"router.shed.queue_full\":5}"
        );
        let text = r.to_string();
        assert!(text.contains("router.requests 42"), "{text}");
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.record(5);
        t.record(3);
        assert_eq!(t.items(), 8);
        assert!(t.per_sec() > 0.0);
    }
}
