//! Serving metrics: latency summaries and throughput accounting.

use std::time::Duration;

/// Latency summary over a set of samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
    pub max: Duration,
}

/// Summarize a sample set (empty input → all-zero summary).
pub fn summarize(samples: &[Duration]) -> Summary {
    if samples.is_empty() {
        let z = Duration::ZERO;
        return Summary { count: 0, mean: z, p50: z, p90: z, p99: z, max: z };
    }
    let mut sorted = samples.to_vec();
    sorted.sort();
    let pct = |p: f64| {
        let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[i]
    };
    let total: Duration = sorted.iter().sum();
    Summary {
        count: sorted.len(),
        mean: total / sorted.len() as u32,
        p50: pct(0.50),
        p90: pct(0.90),
        p99: pct(0.99),
        max: *sorted.last().unwrap(),
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        use crate::util::bench::fmt_dur;
        write!(
            f,
            "n={} mean={} p50={} p90={} p99={} max={}",
            self.count,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p90),
            fmt_dur(self.p99),
            fmt_dur(self.max)
        )
    }
}

/// Simple throughput window: items per second of wall-clock.
#[derive(Debug)]
pub struct Throughput {
    started: std::time::Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput { started: std::time::Instant::now(), items: 0 }
    }

    pub fn record(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn items(&self) -> u64 {
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        let s = summarize(&samples);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, Duration::from_millis(51)); // round((100-1)*0.5)=50 → sorted[50]=51ms
        assert_eq!(s.p99, Duration::from_millis(99));
        assert_eq!(s.max, Duration::from_millis(100));
    }

    #[test]
    fn empty_summary() {
        let s = summarize(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.max, Duration::ZERO);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.record(5);
        t.record(3);
        assert_eq!(t.items(), 8);
        assert!(t.per_sec() > 0.0);
    }
}
