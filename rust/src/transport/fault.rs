//! Deterministic wire-fault injection — the transport-level sibling of
//! [`crate::elastic::chaos`].
//!
//! PR 4's `ChaosSchedule` kills *nodes* at virtual-time boundaries; this
//! module faults *frames*. A [`FaultSchedule`] is a pure function of
//! `(nodes, seed, windows, window_ops)` that scripts wire faults — frame
//! drop, delivery delay, duplication, payload corruption, one-way
//! partition, slow-link throttle — against a per-sender **operation
//! index**: the `k`-th `send` a node performs, counted across its whole
//! lifetime. Indexing by operation rather than wall time is what makes the
//! schedule replay identically on both fabrics: the lockstep protocol
//! performs the same sends in the same order whether the fabric is
//! [`crate::cluster::SimExchange`] channels or a
//! [`crate::transport::tcp::TcpExchange`] socket mesh.
//!
//! [`FaultExchange`] wraps either fabric behind the same
//! [`Exchange`] trait and applies the schedule on the send path:
//!
//! * **Drop** — the frame never reaches the peer; the receiver's bounded
//!   wait surfaces a typed [`TransportError::Deadline`] (sim) or heartbeat
//!   staleness (tcp), and the inference is retried by the replay layer.
//! * **Corrupt** — the frame is encoded, one payload byte is flipped, and
//!   the decode is attempted exactly as a receiver would: the FNV-1a
//!   checksum catches it and the typed
//!   [`CodecError::BadChecksum`] surfaces as a
//!   [`TransportError::Codec`]. Corruption can *never* become wrong
//!   numerics — the flipped frame is rejected before any tensor math.
//! * **Duplicate** — a stray second copy of the frame is delivered tagged
//!   for a phantom future boundary; the receiver's reordering buffer
//!   absorbs it without displacing a real patch (extra frames are
//!   tolerated, not trusted).
//! * **Delay / SlowLink** — the send is stalled (one-shot / for a window
//!   of ops); numerics are unaffected, only latency.
//! * **PartitionTo** — every frame to one destination is dropped for a
//!   window of ops: a one-way partition, detected exactly like drops.
//!
//! The injected op index keeps counting **across replays**: a retried
//! inference starts where the aborted one left off, so a one-shot fault is
//! not re-injected forever and a windowed fault expires after a bounded
//! number of attempts. (The daemon persists the offset across plan
//! generations for the same reason.)
//!
//! [`run_faulted`] is the in-process drill: it replays a schedule against
//! a simulated mesh with bounded recv deadlines, re-executing faulted
//! inferences under a replay budget and auditing the replay-layer
//! invariant end to end — every request completes bit-identical to the
//! single-node reference, or is explicitly failed once the budget is
//! exhausted. Never a silent drop, never a diverged output.

use std::sync::Arc;
use std::time::Duration;

use super::codec::{self, Frame, WireMsg};
use super::{Exchange, TransportError};
use crate::compute::{run_reference, PatchStore, RegionTensor, Tensor, WeightStore};
use crate::model::Model;
use crate::partition::Plan;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Boundary tag for duplicated frames: far beyond any real boundary, so
/// receivers buffer the stray copy as "ahead" instead of letting it
/// displace a real patch or trip the stale-message check.
const DUP_BOUNDARY: usize = u32::MAX as usize;

/// One injectable wire fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireFault {
    /// The frame is silently lost.
    Drop,
    /// The frame is delivered after `micros` microseconds.
    Delay { micros: u64 },
    /// A second copy of the frame is delivered.
    Duplicate,
    /// One payload byte is flipped on the wire.
    Corrupt,
    /// Frames to `dst` are lost (one-way partition) for the event's span.
    PartitionTo { dst: usize },
    /// Every send is throttled by `micros` microseconds for the span.
    SlowLink { micros: u64 },
}

impl WireFault {
    fn kind(&self) -> &'static str {
        match self {
            WireFault::Drop => "drop",
            WireFault::Delay { .. } => "delay",
            WireFault::Duplicate => "duplicate",
            WireFault::Corrupt => "corrupt",
            WireFault::PartitionTo { .. } => "partition",
            WireFault::SlowLink { .. } => "slow_link",
        }
    }
}

/// One scheduled fault: applies to sender `src`'s send operations with
/// index in `[at, at + span)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub src: usize,
    /// First affected send-op index (absolute, lifetime-cumulative).
    pub at: u64,
    /// Number of consecutive ops affected (1 for one-shot faults).
    pub span: u64,
    pub fault: WireFault,
}

/// A deterministic wire-fault schedule for an `nodes`-sender cluster,
/// indexed by per-sender send-operation count. Pure in
/// `(nodes, seed, windows, window_ops)`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    pub nodes: usize,
    pub seed: u64,
    /// Ops per scheduling window.
    pub window_ops: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// Generate a single-fault-per-window schedule over
    /// `windows × window_ops` send operations. Window 0 always corrupts a
    /// frame — every generated schedule proves the checksum path, the way
    /// every `ChaosSchedule` strikes the leader. Later windows roll one of
    /// the six faults or stay quiet; windowed faults (partition,
    /// slow-link) never cross their window, so any op index is under at
    /// most one fault.
    pub fn generate(nodes: usize, seed: u64, windows: usize, window_ops: u64) -> FaultSchedule {
        assert!(nodes >= 2, "wire faults need at least two endpoints");
        assert!(windows >= 1 && window_ops >= 8, "degenerate fault window");
        let mut rng = Rng::new(seed ^ 0x00fa_17a5_c4ed_0137);
        let mut events = Vec::new();
        for w in 0..windows as u64 {
            let src = rng.below(nodes);
            // keep the strike in the first half so windowed spans fit
            let at = w * window_ops + rng.below((window_ops / 2) as usize) as u64;
            let window_end = (w + 1) * window_ops;
            let long_span = (window_ops / 4).max(1).min(window_end - at);
            let roll = if w == 0 { 0.55 } else { rng.f64() };
            let (fault, span) = if roll < 0.18 {
                (WireFault::Drop, 1)
            } else if roll < 0.36 {
                (WireFault::Delay { micros: rng.range(200, 2000) as u64 }, 1)
            } else if roll < 0.50 {
                (WireFault::Duplicate, 1)
            } else if roll < 0.68 {
                (WireFault::Corrupt, 1)
            } else if roll < 0.82 {
                let dst = (src + 1 + rng.below(nodes - 1)) % nodes;
                (WireFault::PartitionTo { dst }, long_span)
            } else if roll < 0.92 {
                (WireFault::SlowLink { micros: rng.range(50, 300) as u64 }, long_span)
            } else {
                continue; // quiet window
            };
            events.push(FaultEvent { src, at, span, fault });
        }
        FaultSchedule { nodes, seed, window_ops, events }
    }

    /// The empty schedule: a transparent [`FaultExchange`].
    pub fn none(nodes: usize) -> FaultSchedule {
        FaultSchedule { nodes, seed: 0, window_ops: u64::MAX, events: Vec::new() }
    }

    /// Number of scheduled fault events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Last op index any event covers (exclusive).
    pub fn horizon_ops(&self) -> u64 {
        self.events.iter().map(|e| e.at.saturating_add(e.span)).max().unwrap_or(0)
    }

    /// The fault (if any) governing sender `src`'s `op`-th send to `to`.
    pub fn fault_for(&self, src: usize, to: usize, op: u64) -> Option<WireFault> {
        self.events
            .iter()
            .find(|e| {
                e.src == src
                    && op >= e.at
                    && op - e.at < e.span
                    && match e.fault {
                        WireFault::PartitionTo { dst } => dst == to,
                        _ => true,
                    }
            })
            .map(|e| e.fault)
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("kind", Json::Str(e.fault.kind().into())),
                    ("src", Json::Num(e.src as f64)),
                    ("at", Json::Num(e.at as f64)),
                    ("span", Json::Num(e.span as f64)),
                ];
                match e.fault {
                    WireFault::Delay { micros } | WireFault::SlowLink { micros } => {
                        fields.push(("micros", Json::Num(micros as f64)));
                    }
                    WireFault::PartitionTo { dst } => {
                        fields.push(("dst", Json::Num(dst as f64)));
                    }
                    _ => {}
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("nodes", Json::Num(self.nodes as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("window_ops", Json::Num(self.window_ops as f64)),
            ("events", Json::Arr(events)),
        ])
    }
}

/// What a [`FaultExchange`] actually injected — per-kind counters, summed
/// across nodes and replays by the drill/daemon plumbing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultLog {
    pub drops: u64,
    pub delays: u64,
    pub dups: u64,
    pub corrupts: u64,
    pub partition_drops: u64,
    pub throttled: u64,
}

impl FaultLog {
    pub fn total(&self) -> u64 {
        self.drops + self.delays + self.dups + self.corrupts + self.partition_drops + self.throttled
    }

    pub fn absorb(&mut self, other: &FaultLog) {
        self.drops += other.drops;
        self.delays += other.delays;
        self.dups += other.dups;
        self.corrupts += other.corrupts;
        self.partition_drops += other.partition_drops;
        self.throttled += other.throttled;
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("drops", Json::Num(self.drops as f64)),
            ("delays", Json::Num(self.delays as f64)),
            ("dups", Json::Num(self.dups as f64)),
            ("corrupts", Json::Num(self.corrupts as f64)),
            ("partition_drops", Json::Num(self.partition_drops as f64)),
            ("throttled", Json::Num(self.throttled as f64)),
        ])
    }
}

/// A fault-injecting wrapper around either fabric. Send operations are
/// counted (cumulatively, across replays — see the module docs) and the
/// schedule consulted per op; the receive path is forwarded untouched,
/// because every injected fault manifests at the receiver through the
/// wire itself (a missing patch, a stray duplicate, a torn connection).
pub struct FaultExchange<E: Exchange> {
    inner: E,
    node: usize,
    schedule: Arc<FaultSchedule>,
    ops: u64,
    log: FaultLog,
}

impl<E: Exchange> FaultExchange<E> {
    pub fn new(inner: E, node: usize, schedule: Arc<FaultSchedule>) -> FaultExchange<E> {
        FaultExchange::with_offset(inner, node, schedule, 0)
    }

    /// Resume the op counter at `offset` — how replays and new plan
    /// generations keep the fault clock moving instead of re-injecting
    /// the same fault forever.
    pub fn with_offset(
        inner: E,
        node: usize,
        schedule: Arc<FaultSchedule>,
        offset: u64,
    ) -> FaultExchange<E> {
        FaultExchange { inner, node, schedule, ops: offset, log: FaultLog::default() }
    }

    /// Cumulative send-op count (offset included).
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// What this wrapper injected since construction.
    pub fn log(&self) -> FaultLog {
        self.log
    }

    /// The wrapped fabric (e.g. to reach `TcpExchange::set_seq`).
    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    /// Model the on-wire corruption of `patch`'s frame: encode it exactly
    /// as the tcp fabric would, flip one payload byte, and decode as the
    /// receiver would. The FNV-1a checksum must reject it — the typed
    /// error is returned in place of a delivery, so a corrupted frame can
    /// never become wrong numerics.
    fn corrupt(&self, boundary: usize, patch: RegionTensor, op: u64) -> TransportError {
        let frame = Frame {
            node: self.node as u32,
            term: 0,
            msg: WireMsg::Patch { seq: 0, boundary: boundary as u32, patch },
        };
        let mut bytes = codec::encode(&frame);
        let payload_len = bytes.len() - codec::HEADER_LEN;
        let pos = codec::HEADER_LEN + (op as usize % payload_len);
        bytes[pos] ^= 0x01;
        match codec::decode(&bytes) {
            Err(e) => TransportError::Codec(e),
            Ok(_) => TransportError::Protocol("corrupted frame decoded cleanly".into()),
        }
    }
}

impl<E: Exchange> Exchange for FaultExchange<E> {
    fn send(
        &mut self,
        to: usize,
        boundary: usize,
        patch: RegionTensor,
    ) -> Result<(), TransportError> {
        let op = self.ops;
        self.ops += 1;
        match self.schedule.fault_for(self.node, to, op) {
            None => self.inner.send(to, boundary, patch),
            Some(WireFault::Drop) => {
                self.log.drops += 1;
                Ok(())
            }
            Some(WireFault::PartitionTo { .. }) => {
                self.log.partition_drops += 1;
                Ok(())
            }
            Some(WireFault::Delay { micros }) => {
                self.log.delays += 1;
                std::thread::sleep(Duration::from_micros(micros));
                self.inner.send(to, boundary, patch)
            }
            Some(WireFault::SlowLink { micros }) => {
                self.log.throttled += 1;
                std::thread::sleep(Duration::from_micros(micros));
                self.inner.send(to, boundary, patch)
            }
            Some(WireFault::Duplicate) => {
                self.log.dups += 1;
                self.inner.send(to, DUP_BOUNDARY, patch.clone())?;
                self.inner.send(to, boundary, patch)
            }
            Some(WireFault::Corrupt) => {
                self.log.corrupts += 1;
                Err(self.corrupt(boundary, patch, op))
            }
        }
    }

    fn recv_for(
        &mut self,
        boundary: usize,
        expect: usize,
        store: &mut PatchStore,
    ) -> Result<(), TransportError> {
        self.inner.recv_for(boundary, expect, store)
    }
}

/// Audit of one [`run_faulted`] drill.
#[derive(Debug, Clone)]
pub struct FaultDrillOutcome {
    pub seed: u64,
    /// Fault events the schedule scripted.
    pub events: usize,
    pub requests: u64,
    /// Requests that completed (possibly after replays).
    pub ok: u64,
    /// Requests explicitly failed after the replay budget was exhausted.
    pub failed: u64,
    /// Re-executions performed (attempts beyond each request's first).
    pub replay_attempts: u64,
    /// Completed outputs that diverged from the reference. Must be 0.
    pub mismatches: u64,
    /// What the wrappers actually injected, all nodes and attempts summed.
    pub injected: FaultLog,
}

impl FaultDrillOutcome {
    /// The replay-layer invariant: every request is accounted for —
    /// completed or explicitly failed — and no completed output ever
    /// diverged. (Single-fault schedules with a sane budget additionally
    /// expect `failed == 0`; callers assert that on top.)
    pub fn verify(&self) -> Result<(), String> {
        let mut errs = Vec::new();
        if self.ok + self.failed != self.requests {
            errs.push(format!(
                "accounting hole: {} ok + {} failed != {} requests",
                self.ok, self.failed, self.requests
            ));
        }
        if self.mismatches != 0 {
            errs.push(format!("{} outputs diverged from the reference", self.mismatches));
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("events", Json::Num(self.events as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("replay_attempts", Json::Num(self.replay_attempts as f64)),
            ("mismatches", Json::Num(self.mismatches as f64)),
            ("injected", self.injected.to_json()),
        ])
    }
}

impl std::fmt::Display for FaultDrillOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "seed={} events={} requests={} ok={} failed={} replays={} mismatches={} injected={}",
            self.seed,
            self.events,
            self.requests,
            self.ok,
            self.failed,
            self.replay_attempts,
            self.mismatches,
            self.injected.total()
        )
    }
}

/// Replay `schedule` against a simulated mesh: serve `requests`
/// deterministic inputs through the lockstep protocol with every node's
/// fabric wrapped in a [`FaultExchange`], re-executing any inference a
/// fault aborts (up to `replay_budget` re-runs per request) and checking
/// each completed output bit-for-bit against the single-node reference.
/// `recv_deadline` bounds every blocked wait, so drops surface as typed
/// deadline errors instead of hangs. Per-node op offsets persist across
/// attempts — the drill-side twin of the daemon's cross-generation fault
/// clock.
#[allow(clippy::too_many_arguments)]
pub fn run_faulted(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    schedule: &FaultSchedule,
    requests: u64,
    input_seed: u64,
    replay_budget: u32,
    recv_deadline: Duration,
) -> FaultDrillOutcome {
    let nodes = schedule.nodes;
    let (blocks, geos) = crate::cluster::plan_geometry(model, plan, nodes);
    let blocks = Arc::new(blocks);
    let geos = Arc::new(geos);
    let model = Arc::new(model.clone());
    let weights = Arc::new(weights.clone());
    let sched = Arc::new(schedule.clone());

    let mut offsets = vec![0u64; nodes];
    let mut injected = FaultLog::default();
    let (mut ok, mut failed, mut replay_attempts, mut mismatches) = (0u64, 0u64, 0u64, 0u64);
    let l0 = &model.layers[0];
    for i in 0..requests {
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, input_seed + i);
        let reference = run_reference(&model, &weights, &input);
        let mut done = false;
        for attempt in 0..=replay_budget {
            if attempt > 0 {
                replay_attempts += 1;
            }
            let run = faulted_attempt(
                &model,
                &blocks,
                &geos,
                &weights,
                &input,
                &sched,
                &mut offsets,
                &mut injected,
                recv_deadline,
            );
            if let Ok(output) = run {
                if reference.max_abs_diff(&output) != 0.0 {
                    mismatches += 1;
                }
                ok += 1;
                done = true;
                break;
            }
        }
        if !done {
            failed += 1;
        }
    }
    FaultDrillOutcome {
        seed: schedule.seed,
        events: schedule.len(),
        requests,
        ok,
        failed,
        replay_attempts,
        mismatches,
        injected,
    }
}

/// One lockstep inference over a fresh fault-wrapped simulated mesh.
/// Always advances `offsets` and absorbs the injection log, success or
/// not — the fault clock never rewinds.
#[allow(clippy::too_many_arguments)]
fn faulted_attempt(
    model: &Arc<Model>,
    blocks: &Arc<Vec<(usize, usize, crate::partition::Scheme)>>,
    geos: &Arc<Vec<crate::partition::inflate::BlockGeometry>>,
    weights: &Arc<WeightStore>,
    input: &Tensor,
    sched: &Arc<FaultSchedule>,
    offsets: &mut [u64],
    injected: &mut FaultLog,
    recv_deadline: Duration,
) -> Result<Tensor, TransportError> {
    let nodes = sched.nodes;
    let mesh = crate::cluster::sim_mesh(nodes, recv_deadline);
    let mut handles = Vec::with_capacity(nodes);
    for (node, ex) in mesh.into_iter().enumerate() {
        let model = Arc::clone(model);
        let blocks = Arc::clone(blocks);
        let geos = Arc::clone(geos);
        let weights = Arc::clone(weights);
        let sched = Arc::clone(sched);
        let input = (node == 0).then(|| input.clone());
        let offset = offsets[node];
        handles.push(std::thread::spawn(move || {
            let mut ex = FaultExchange::with_offset(ex, node, sched, offset);
            let r = crate::cluster::node_main(
                node,
                nodes,
                &model,
                &blocks,
                &geos,
                &weights,
                input.as_ref(),
                &mut ex,
                &crate::compute::ComputeConfig::default(),
            );
            (r, ex.ops(), ex.log())
        }));
    }
    let mut output: Option<Tensor> = None;
    let mut err: Option<TransportError> = None;
    for (node, h) in handles.into_iter().enumerate() {
        let (r, ops, log) = h.join().expect("fault-drill node thread panicked");
        offsets[node] = ops;
        injected.absorb(&log);
        match r {
            Ok(res) => {
                if node == 0 {
                    output = res.output;
                }
            }
            Err(e) => err = Some(err.unwrap_or(e)),
        }
    }
    match (output, err) {
        (Some(t), None) => Ok(t),
        (_, Some(e)) => Err(e),
        (None, None) => Err(TransportError::Protocol("leader produced no output".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim_mesh;
    use crate::model::zoo;
    use crate::partition::{Region, Scheme};

    fn patch() -> RegionTensor {
        let r = Region::new(0, 2, 0, 2, 0, 1);
        RegionTensor::new(r, Tensor::random(2, 2, 1, 3))
    }

    fn one_event(src: usize, at: u64, span: u64, fault: WireFault) -> FaultSchedule {
        FaultSchedule {
            nodes: 2,
            seed: 0,
            window_ops: 64,
            events: vec![FaultEvent { src, at, span, fault }],
        }
    }

    #[test]
    fn schedules_are_deterministic_and_seed_sensitive() {
        let a = FaultSchedule::generate(3, 11, 6, 256);
        let b = FaultSchedule::generate(3, 11, 6, 256);
        assert_eq!(a, b);
        let c = FaultSchedule::generate(3, 12, 6, 256);
        assert_ne!(a.events, c.events, "different seeds must differ");
        assert!(!a.is_empty());
    }

    #[test]
    fn one_fault_per_window_and_spans_stay_inside() {
        for seed in 0..10u64 {
            let s = FaultSchedule::generate(4, seed, 8, 128);
            let mut windows_hit = Vec::new();
            for e in &s.events {
                let w = e.at / s.window_ops;
                assert_eq!((e.at + e.span - 1) / s.window_ops, w, "span crosses its window");
                windows_hit.push(w);
            }
            let mut dedup = windows_hit.clone();
            dedup.dedup();
            assert_eq!(windows_hit, dedup, "two faults in one window (seed {seed})");
            // window 0 always proves the checksum path
            let first = s.events.first().expect("window 0 is never quiet");
            assert_eq!(first.at / s.window_ops, 0);
            assert_eq!(first.fault, WireFault::Corrupt);
        }
    }

    #[test]
    fn partition_only_applies_to_its_destination() {
        let s = one_event(0, 4, 8, WireFault::PartitionTo { dst: 1 });
        assert_eq!(s.fault_for(0, 1, 4), Some(WireFault::PartitionTo { dst: 1 }));
        assert_eq!(s.fault_for(0, 1, 11), Some(WireFault::PartitionTo { dst: 1 }));
        assert_eq!(s.fault_for(0, 1, 12), None, "window expired");
        assert_eq!(s.fault_for(0, 0, 4), None, "other destinations unaffected");
        assert_eq!(s.fault_for(1, 1, 4), None, "other senders unaffected");
    }

    #[test]
    fn schedule_json_lists_every_event() {
        let s = FaultSchedule::generate(3, 5, 6, 64);
        let j = s.to_json();
        assert_eq!(j.get("nodes").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("window_ops").and_then(Json::as_usize), Some(64));
        let events = j.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), s.len());
        assert_eq!(events[0].get("kind").and_then(Json::as_str), Some("corrupt"));
    }

    #[test]
    fn corrupt_frame_is_caught_by_checksum_on_sim_fabric() {
        // the acceptance invariant, sim side: a corrupted frame surfaces
        // as the typed checksum error — never delivered, never numerics
        let mut mesh = sim_mesh(2, Duration::from_millis(50));
        let sched = Arc::new(one_event(0, 0, 1, WireFault::Corrupt));
        let mut ex = FaultExchange::new(mesh.remove(0), 0, Arc::clone(&sched));
        let err = ex.send(1, 0, patch()).unwrap_err();
        assert!(
            matches!(err, TransportError::Codec(codec::CodecError::BadChecksum { .. })),
            "expected BadChecksum, got {err:?}"
        );
        assert_eq!(ex.log().corrupts, 1);
        // the very next op is past the one-shot fault: the retry is clean
        ex.send(1, 0, patch()).unwrap();
        let mut store = PatchStore::new();
        mesh.remove(0).recv_for(0, 1, &mut store).unwrap();
    }

    #[test]
    fn dropped_frame_surfaces_as_typed_deadline() {
        let mut mesh = sim_mesh(2, Duration::from_millis(40));
        let mut receiver = mesh.pop().unwrap();
        let sched = Arc::new(one_event(0, 0, 1, WireFault::Drop));
        let mut ex = FaultExchange::new(mesh.pop().unwrap(), 0, sched);
        ex.send(1, 0, patch()).unwrap(); // injected: silently dropped
        assert_eq!(ex.log().drops, 1);
        let mut store = PatchStore::new();
        let err = receiver.recv_for(0, 1, &mut store).unwrap_err();
        assert_eq!(err, TransportError::Deadline { boundary: 0, got: 0, expect: 1 });
    }

    #[test]
    fn duplicate_is_buffered_ahead_not_double_counted() {
        let mut mesh = sim_mesh(2, Duration::from_millis(100));
        let mut receiver = mesh.pop().unwrap();
        let sched = Arc::new(one_event(0, 0, 1, WireFault::Duplicate));
        let mut ex = FaultExchange::new(mesh.pop().unwrap(), 0, sched);
        ex.send(1, 0, patch()).unwrap();
        ex.send(1, 0, patch()).unwrap(); // clean second send
        assert_eq!(ex.log().dups, 1);
        // the receiver sees exactly the two real patches; the stray copy
        // parks in the reorder buffer without tripping the stale check
        let mut store = PatchStore::new();
        receiver.recv_for(0, 2, &mut store).unwrap();
        assert_eq!(store.patches.len(), 2);
    }

    #[test]
    fn offsets_move_the_fault_clock_across_attempts() {
        let s = one_event(0, 3, 1, WireFault::Drop);
        let sched = Arc::new(s);
        let mut mesh = sim_mesh(2, Duration::from_millis(20));
        let mut ex = FaultExchange::with_offset(mesh.remove(0), 0, sched, 4);
        ex.send(1, 0, patch()).unwrap();
        assert_eq!(ex.log().drops, 0, "op 4 is past the fault at op 3");
        assert_eq!(ex.ops(), 5);
    }

    #[test]
    fn benign_faults_preserve_numerics_without_replay() {
        // delays, throttles and duplicates never abort an inference —
        // outputs must match the reference with zero replays
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let schedule = FaultSchedule {
            nodes: 3,
            seed: 1,
            window_ops: 64,
            events: vec![
                FaultEvent { src: 0, at: 1, span: 1, fault: WireFault::Delay { micros: 400 } },
                FaultEvent { src: 1, at: 2, span: 1, fault: WireFault::Duplicate },
                FaultEvent {
                    src: 2,
                    at: 4,
                    span: 12,
                    fault: WireFault::SlowLink { micros: 100 },
                },
            ],
        };
        let out =
            run_faulted(&model, &plan, &weights, &schedule, 2, 700, 3, Duration::from_millis(400));
        out.verify().expect("fault invariants violated");
        assert_eq!(out.ok, 2, "benign faults must not fail requests: {out}");
        assert_eq!(out.replay_attempts, 0, "benign faults must not trigger replay: {out}");
        assert!(out.injected.total() >= 3, "schedule injected nothing: {out}");
    }

    #[test]
    fn disruptive_faults_recover_through_replay() {
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let schedule = FaultSchedule {
            nodes: 3,
            seed: 2,
            window_ops: 64,
            events: vec![
                FaultEvent { src: 0, at: 0, span: 1, fault: WireFault::Corrupt },
                FaultEvent { src: 1, at: 20, span: 1, fault: WireFault::Drop },
            ],
        };
        let out =
            run_faulted(&model, &plan, &weights, &schedule, 3, 800, 5, Duration::from_millis(250));
        out.verify().expect("fault invariants violated");
        assert_eq!(out.ok, 3, "single-fault windows must end with ok == requests: {out}");
        assert!(out.replay_attempts >= 1, "disruptive faults must exercise replay: {out}");
        assert_eq!(out.mismatches, 0);
        assert!(out.injected.corrupts >= 1 && out.injected.drops >= 1, "{out}");
    }

    #[test]
    fn exhausted_replay_budget_fails_explicitly() {
        // a fault pinned to every op: no attempt can succeed, and the
        // drill must degrade to explicit failure — the accounting
        // invariant (ok + failed == requests) is exactly what the serving
        // layer preserves when ITS budget runs out
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let schedule = FaultSchedule {
            nodes: 3,
            seed: 3,
            window_ops: 64,
            events: vec![FaultEvent { src: 0, at: 0, span: u64::MAX, fault: WireFault::Corrupt }],
        };
        let out =
            run_faulted(&model, &plan, &weights, &schedule, 2, 900, 1, Duration::from_millis(150));
        out.verify().expect("accounting must hold even at budget exhaustion");
        assert_eq!(out.ok, 0);
        assert_eq!(out.failed, 2);
        assert_eq!(out.replay_attempts, 2, "one replay per request at budget 1");
    }
}
