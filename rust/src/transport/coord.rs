//! Coordinator: plan distribution, dispatch, and failover for a cluster
//! of node daemons.
//!
//! [`ProcessCluster`] is the process-mode counterpart of calling
//! [`crate::cluster::run_distributed`] in-process: resolve the live
//! daemon set from the [`super::registry`], install the plan (term-,
//! model-, and peer-stamped) on every member, then serve inferences one
//! lockstep batch at a time — `Begin` to workers, `Infer` to the leader,
//! `Output` back.
//!
//! **Failure contract** (the PR 4 chaos invariants, now over real
//! processes): every submitted inference ends in exactly one of
//! [`InferOutcome::Done`] or [`InferOutcome::Failed`] — zero silent
//! drops. A failure names the dead node when the evidence identifies it
//! (leader's `Failed` frame, a control-connection EOF); the caller then
//! [`ProcessCluster::reinstall`]s, which re-resolves the registry (the
//! real liveness signal — a killed daemon's lease ages out), bans the
//! known-dead id, re-elects the leader as the **lowest surviving node
//! id** (the same rank rule as [`crate::cluster::election`]), bumps the
//! term, and re-installs. Retried inferences are bit-identical to what
//! the full cluster would have produced, because the numerics are
//! node-count-invariant.

use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::compute::Tensor;
use crate::model::Model;
use crate::partition::Plan;
use crate::trace::SpanRecord;
use crate::transport::codec::{Frame, RegistryEntry, WireMsg, CTL_NODE};
use crate::transport::tcp::{self, Stream};
use crate::transport::{registry, RetryPolicy, TransportError};

enum CtlEvent {
    Ready {
        node: u32,
        term: u64,
    },
    Output {
        seq: u64,
        output: Tensor,
        bytes: u64,
        msgs: u64,
        traffic: Vec<(u64, u64)>,
        trace: u64,
        service_ns: u64,
    },
    Failed {
        seq: u64,
        culprit: u32,
    },
    TraceData {
        node: u32,
        spans: Vec<SpanRecord>,
        rss_bytes: u64,
        cpu_ms: u64,
    },
    Eof {
        node: u32,
    },
}

/// One completed process-mode inference.
#[derive(Debug)]
pub struct ProcessRun {
    pub seq: u64,
    pub output: Tensor,
    /// Leader-side payload bytes sent (scatter + its boundary shares).
    pub bytes: u64,
    pub msgs: u64,
    pub traffic: Vec<(u64, u64)>,
    /// Trace id echoed by the leader (0 = untraced).
    pub trace: u64,
    /// Leader-measured compute wall time for this inference.
    pub service_ns: u64,
    /// Coordinator-measured dispatch→output round trip. Clocks across
    /// processes are unsynchronized, so wire time is *derived*:
    /// `roundtrip − service`, both measured locally by their owner.
    pub roundtrip_ns: u64,
    /// The plan generation (term) that served this inference.
    pub term: u64,
}

/// One daemon's answer to a [`ProcessCluster::trace_dump`] RPC.
#[derive(Debug)]
pub struct NodeTraceDump {
    pub node: u32,
    pub spans: Vec<SpanRecord>,
    /// RSS gauge at dump time (0 when `/proc` is absent).
    pub rss_bytes: u64,
    /// CPU-ms consumed since daemon boot.
    pub cpu_ms: u64,
}

/// Every inference ends in exactly one of these — the zero-silent-drop
/// contract.
#[derive(Debug)]
pub enum InferOutcome {
    Done(ProcessRun),
    /// Explicit failure; `dead` names the culprit when known (else the
    /// registry's lease expiry identifies it on the next reinstall).
    Failed { seq: u64, dead: Option<u32> },
}

/// How one [`ProcessCluster::infer_with_recovery`] request ended.
#[derive(Debug)]
pub enum RecoveryOutcome {
    /// Completed — possibly only after replays on a rebuilt cluster.
    Done(ProcessRun),
    /// The replay budget ran out; the cluster is rebuilt and healthy, but
    /// this request is explicitly failed (today's pre-replay behavior).
    Exhausted,
    /// The cluster could not be rebuilt at all — no surviving daemons, or
    /// the coordinator's own channel tore.
    Dead,
}

/// [`ProcessCluster::infer_with_recovery`]'s audit trail: what it took to
/// reach the outcome.
#[derive(Debug)]
pub struct RecoveryReport {
    pub outcome: RecoveryOutcome,
    /// Re-executions beyond the request's first attempt.
    pub replays: u32,
    /// Reinstalls (registry re-resolve + re-election) performed.
    pub failovers: u32,
}

struct Member {
    entry: RegistryEntry,
    writer: Stream,
}

/// Coordinator handle over a set of live daemons.
pub struct ProcessCluster {
    registry: String,
    term: u64,
    members: Vec<Member>,
    events: Receiver<CtlEvent>,
    events_tx: Sender<CtlEvent>,
    next_seq: u64,
    model: Option<Model>,
    plan: Option<Plan>,
    seed: u64,
    banned: BTreeSet<u32>,
    /// Bound on one inference round trip.
    pub infer_deadline: Duration,
    /// Bound on plan installation (mesh bring-up included).
    pub ready_deadline: Duration,
    /// Control-plane retry policy: registry resolves and member dials.
    pub retry: RetryPolicy,
}

impl ProcessCluster {
    /// Wait until at least `min_nodes` daemons hold live leases, then
    /// return a coordinator (no plan installed yet).
    pub fn connect(
        registry_addr: &str,
        min_nodes: usize,
        deadline: Duration,
    ) -> Result<ProcessCluster, TransportError> {
        registry::await_nodes(registry_addr, min_nodes, deadline)?;
        let (events_tx, events) = channel();
        Ok(ProcessCluster {
            registry: registry_addr.to_string(),
            term: 0,
            members: Vec::new(),
            events,
            events_tx,
            next_seq: 0,
            model: None,
            plan: None,
            seed: 0,
            banned: BTreeSet::new(),
            infer_deadline: Duration::from_secs(60),
            ready_deadline: Duration::from_secs(30),
            retry: RetryPolicy { deadline: Duration::from_secs(5), ..RetryPolicy::default() },
        })
    }

    pub fn nodes(&self) -> usize {
        self.members.len()
    }

    /// The current leader: lowest surviving node id, rank 0.
    pub fn leader(&self) -> u32 {
        self.members.first().map(|m| m.entry.node).expect("no members installed")
    }

    pub fn member_ids(&self) -> Vec<u32> {
        self.members.iter().map(|m| m.entry.node).collect()
    }

    /// Install `plan` for `model` on the live daemon set (weights derive
    /// from `seed` on each daemon).
    pub fn install(
        &mut self,
        model: &Model,
        plan: &Plan,
        seed: u64,
    ) -> Result<(), TransportError> {
        self.model = Some(model.clone());
        self.plan = Some(plan.clone());
        self.seed = seed;
        self.reinstall(None)
    }

    /// Rebuild the generation on the surviving daemons: ban `exclude` (if
    /// any), re-resolve the registry, re-elect, bump the term, reinstall,
    /// and wait for every member's `Ready`.
    pub fn reinstall(&mut self, exclude: Option<u32>) -> Result<(), TransportError> {
        if let Some(dead) = exclude {
            self.banned.insert(dead);
        }
        let model = self.model.clone().ok_or_else(|| {
            TransportError::Protocol("reinstall before install: no plan to distribute".into())
        })?;
        let plan = self.plan.clone().unwrap();

        'attempt: for attempt in 0..5 {
            let mut entries = registry::resolve_with(&self.retry, &self.registry)?;
            entries.retain(|e| !self.banned.contains(&e.node));
            if entries.is_empty() {
                return Err(TransportError::Protocol("no surviving daemons".into()));
            }
            // entries arrive sorted by node id: rank 0 = lowest id = the
            // same leader election::elect_leader would pick
            self.term += 1;
            let term = self.term;
            let leader = entries[0].node;
            let peers: Vec<(u32, String)> =
                entries.iter().map(|e| (e.node, e.data_addr.clone())).collect();

            // reuse live control connections; dial new members; drop gone
            let mut old: Vec<Member> = std::mem::take(&mut self.members);
            let mut next: Vec<Member> = Vec::with_capacity(entries.len());
            for e in &entries {
                if let Some(pos) = old.iter().position(|m| m.entry.node == e.node) {
                    next.push(old.swap_remove(pos));
                } else {
                    match self.dial(e) {
                        Ok(m) => next.push(m),
                        Err(_) => {
                            self.banned.insert(e.node);
                            continue 'attempt;
                        }
                    }
                }
            }
            for m in old {
                m.writer.shutdown_both(); // explicit goodbye to ex-members
            }
            self.members = next;

            // broadcast the new generation
            let mut send_failed: Option<u32> = None;
            for m in self.members.iter_mut() {
                let elect = Frame { node: CTL_NODE, term, msg: WireMsg::Elect { leader } };
                let install = Frame {
                    node: CTL_NODE,
                    term,
                    msg: WireMsg::PlanInstall {
                        leader,
                        seed: self.seed,
                        model: model.clone(),
                        plan: plan.clone(),
                        peers: peers.clone(),
                    },
                };
                if tcp::send_frame(&mut m.writer, &elect).is_err()
                    || tcp::send_frame(&mut m.writer, &install).is_err()
                {
                    send_failed = Some(m.entry.node);
                    break;
                }
            }
            if let Some(dead) = send_failed {
                self.banned.insert(dead);
                continue 'attempt;
            }

            // barrier: every member acks Ready for this term
            let mut ready: BTreeSet<u32> = BTreeSet::new();
            let start = Instant::now();
            while ready.len() < self.members.len() {
                if start.elapsed() > self.ready_deadline {
                    let _ = attempt; // retried below with a fresh resolve
                    continue 'attempt;
                }
                match self.events.recv_timeout(Duration::from_millis(20)) {
                    Ok(CtlEvent::Ready { node, term: t }) if t == term => {
                        ready.insert(node);
                    }
                    Ok(CtlEvent::Eof { node }) => {
                        if self.member_ids().contains(&node) {
                            self.banned.insert(node);
                            continue 'attempt;
                        }
                    }
                    Ok(_) | Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(TransportError::Protocol("event channel closed".into()));
                    }
                }
            }
            return Ok(());
        }
        Err(TransportError::Protocol("plan install kept failing after 5 attempts".into()))
    }

    /// Dial every live daemon's control plane **without installing a
    /// plan** — just enough membership for control RPCs that need no
    /// generation, like [`ProcessCluster::trace_dump`]. Daemons serve one
    /// coordinator at a time, so attach only when no serving coordinator
    /// is connected (e.g. after a harness run tore its server down, or
    /// from `flexpie-ctl trace-dump` against an idle cluster).
    pub fn attach(&mut self) -> Result<(), TransportError> {
        let mut entries = registry::resolve_with(&self.retry, &self.registry)?;
        entries.retain(|e| !self.banned.contains(&e.node));
        if entries.is_empty() {
            return Err(TransportError::Protocol("no live daemons to attach to".into()));
        }
        let mut next = Vec::with_capacity(entries.len());
        for e in &entries {
            next.push(self.dial(e)?);
        }
        self.members = next;
        Ok(())
    }

    fn dial(&self, e: &RegistryEntry) -> Result<Member, TransportError> {
        let writer = self
            .retry
            .run("coord.dial", |_| tcp::connect_retry(&e.ctl_addr, self.retry.deadline))?;
        let reader = writer.try_clone()?;
        spawn_ctl_reader(reader, e.node, self.events_tx.clone());
        Ok(Member { entry: e.clone(), writer })
    }

    /// Serve one inference. Always returns an outcome — `Done` with the
    /// gathered output, or an explicit `Failed` naming the evidence.
    pub fn infer(&mut self, input: &Tensor) -> Result<InferOutcome, TransportError> {
        self.infer_traced(input, 0)
    }

    /// [`ProcessCluster::infer`] carrying a trace id: the id rides the
    /// `Begin`/`Infer` frames, the leader echoes it on `Output` with its
    /// measured service time, and the round trip is clocked here — the
    /// three ingredients of the queue/service/wire decomposition.
    pub fn infer_traced(
        &mut self,
        input: &Tensor,
        trace: u64,
    ) -> Result<InferOutcome, TransportError> {
        assert!(!self.members.is_empty(), "install a plan before inferring");
        let seq = self.next_seq;
        self.next_seq += 1;
        let term = self.term;

        // workers first so their exchanges are already listening by the
        // time the leader's scatter lands (buffered either way)
        let start = Instant::now();
        for i in (1..self.members.len()).rev() {
            let frame = Frame { node: CTL_NODE, term, msg: WireMsg::Begin { seq, trace } };
            if tcp::send_frame(&mut self.members[i].writer, &frame).is_err() {
                let dead = self.members[i].entry.node;
                return Ok(InferOutcome::Failed { seq, dead: Some(dead) });
            }
        }
        let infer = Frame {
            node: CTL_NODE,
            term,
            msg: WireMsg::Infer { seq, input: input.clone(), trace },
        };
        if tcp::send_frame(&mut self.members[0].writer, &infer).is_err() {
            let dead = self.members[0].entry.node;
            return Ok(InferOutcome::Failed { seq, dead: Some(dead) });
        }

        loop {
            if start.elapsed() > self.infer_deadline {
                return Ok(InferOutcome::Failed { seq, dead: None });
            }
            match self.events.recv_timeout(Duration::from_millis(20)) {
                Ok(CtlEvent::Output { seq: s, output, bytes, msgs, traffic, trace, service_ns })
                    if s == seq =>
                {
                    return Ok(InferOutcome::Done(ProcessRun {
                        seq,
                        output,
                        bytes,
                        msgs,
                        traffic,
                        trace,
                        service_ns,
                        roundtrip_ns: start.elapsed().as_nanos() as u64,
                        term,
                    }));
                }
                Ok(CtlEvent::Failed { seq: s, culprit }) if s == seq => {
                    let dead = (culprit != CTL_NODE).then_some(culprit);
                    return Ok(InferOutcome::Failed { seq, dead });
                }
                Ok(CtlEvent::Eof { node }) => {
                    if self.member_ids().contains(&node) {
                        return Ok(InferOutcome::Failed { seq, dead: Some(node) });
                    }
                }
                Ok(_) | Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::Protocol("event channel closed".into()));
                }
            }
        }
    }

    /// Serve one inference with replay recovery: an explicit failure
    /// triggers a reinstall (banning the culprit when named — the PR 6
    /// failover path) followed by a **re-execution of the same input** on
    /// the rebuilt cluster, up to `budget` replays. Numerics are
    /// node-count-invariant, so a replayed output is bit-identical to what
    /// the original cluster would have produced. When the budget runs out
    /// the request degrades to today's explicit-failure contract — the
    /// cluster is still rebuilt for the next request, and nothing is ever
    /// silently dropped.
    pub fn infer_with_recovery(&mut self, input: &Tensor, budget: u32) -> RecoveryReport {
        self.infer_with_recovery_traced(input, budget, 0)
    }

    /// [`ProcessCluster::infer_with_recovery`] carrying a trace id. The
    /// trace fields in the returned run describe the **successful** attempt
    /// (failed attempts never produce an `Output`).
    pub fn infer_with_recovery_traced(
        &mut self,
        input: &Tensor,
        budget: u32,
        trace: u64,
    ) -> RecoveryReport {
        let mut replays = 0u32;
        let mut failovers = 0u32;
        loop {
            match self.infer_traced(input, trace) {
                Ok(InferOutcome::Done(run)) => {
                    return RecoveryReport {
                        outcome: RecoveryOutcome::Done(run),
                        replays,
                        failovers,
                    };
                }
                Ok(InferOutcome::Failed { dead, .. }) => {
                    failovers += 1;
                    if self.reinstall(dead).is_err() {
                        return RecoveryReport {
                            outcome: RecoveryOutcome::Dead,
                            replays,
                            failovers,
                        };
                    }
                    if replays >= budget {
                        return RecoveryReport {
                            outcome: RecoveryOutcome::Exhausted,
                            replays,
                            failovers,
                        };
                    }
                    replays += 1;
                }
                Err(_) => {
                    return RecoveryReport { outcome: RecoveryOutcome::Dead, replays, failovers };
                }
            }
        }
    }

    /// Ask every live member for its flight recorder + resource usage
    /// (the `flexpie-ctl trace-dump` RPC). Best-effort per member: a
    /// daemon that dies mid-dump is simply absent from the answer — the
    /// merger marks its trees truncated instead of failing the dump.
    pub fn trace_dump(&mut self) -> Vec<NodeTraceDump> {
        let term = self.term;
        let mut expect: BTreeSet<u32> = BTreeSet::new();
        for m in self.members.iter_mut() {
            let frame = Frame { node: CTL_NODE, term, msg: WireMsg::TraceDump };
            if tcp::send_frame(&mut m.writer, &frame).is_ok() {
                expect.insert(m.entry.node);
            }
        }
        let mut dumps = Vec::new();
        let start = Instant::now();
        while !expect.is_empty() && start.elapsed() < self.infer_deadline {
            match self.events.recv_timeout(Duration::from_millis(20)) {
                Ok(CtlEvent::TraceData { node, spans, rss_bytes, cpu_ms }) => {
                    if expect.remove(&node) {
                        dumps.push(NodeTraceDump { node, spans, rss_bytes, cpu_ms });
                    }
                }
                Ok(CtlEvent::Eof { node }) => {
                    expect.remove(&node);
                }
                Ok(_) | Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        dumps.sort_by_key(|d| d.node);
        dumps
    }

    /// Ask every member daemon to exit, then drop the connections.
    pub fn shutdown(mut self) {
        for m in self.members.iter_mut() {
            let frame = Frame { node: CTL_NODE, term: self.term, msg: WireMsg::Shutdown };
            let _ = tcp::send_frame(&mut m.writer, &frame);
            m.writer.shutdown_both();
        }
    }
}

fn spawn_ctl_reader(mut s: Stream, node: u32, tx: Sender<CtlEvent>) {
    std::thread::spawn(move || loop {
        match tcp::read_frame(&mut s) {
            Ok(f) => {
                let ev = match f.msg {
                    WireMsg::Ready => CtlEvent::Ready { node, term: f.term },
                    WireMsg::Output { seq, output, bytes, msgs, traffic, trace, service_ns } => {
                        CtlEvent::Output { seq, output, bytes, msgs, traffic, trace, service_ns }
                    }
                    WireMsg::Failed { seq, node: culprit } => CtlEvent::Failed { seq, culprit },
                    WireMsg::TraceData { spans, rss_bytes, cpu_ms } => {
                        CtlEvent::TraceData { node, spans, rss_bytes, cpu_ms }
                    }
                    _ => continue,
                };
                if tx.send(ev).is_err() {
                    break;
                }
            }
            Err(_) => {
                let _ = tx.send(CtlEvent::Eof { node });
                break;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::run_reference;
    use crate::compute::WeightStore;
    use crate::model::zoo;
    use crate::partition::{Plan, Scheme};
    use crate::transport::daemon::{self, DaemonOpts};
    use crate::transport::registry::RegistryServer;

    fn spawn_daemons(registry: &str, ids: &[u32]) {
        for &id in ids {
            let opts = DaemonOpts::new(id, registry);
            std::thread::spawn(move || {
                let _ = daemon::run(opts);
            });
        }
    }

    #[test]
    fn three_daemon_cluster_matches_reference_bit_for_bit() {
        // the in-thread version of the process e2e: a real registry, three
        // daemons with real TCP meshes, a coordinator — outputs must equal
        // the single-process reference exactly
        let srv = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_secs(3)).unwrap();
        spawn_daemons(srv.addr(), &[0, 1, 2]);
        let mut pc = ProcessCluster::connect(srv.addr(), 3, Duration::from_secs(10)).unwrap();

        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        pc.install(&model, &plan, 11).unwrap();
        assert_eq!(pc.nodes(), 3);
        assert_eq!(pc.leader(), 0);

        let ws = WeightStore::for_model(&model, 11);
        for seed in 0..3u64 {
            let input = Tensor::random(16, 16, 3, 1000 + seed);
            let reference = run_reference(&model, &ws, &input);
            match pc.infer(&input).unwrap() {
                InferOutcome::Done(run) => {
                    assert_eq!(
                        reference.max_abs_diff(&run.output),
                        0.0,
                        "wire output differs from reference"
                    );
                    assert!(run.bytes > 0, "leader reported no traffic");
                }
                InferOutcome::Failed { dead, .. } => {
                    panic!("healthy cluster failed an inference (dead={dead:?})")
                }
            }
        }

        // traced inference: the id echoes back with a measured
        // decomposition, and a trace-dump finds the leader's service span
        let input = Tensor::random(16, 16, 3, 2000);
        match pc.infer_traced(&input, 77).unwrap() {
            InferOutcome::Done(run) => {
                assert_eq!(run.trace, 77);
                assert!(run.service_ns > 0, "leader must measure its compute");
                assert!(
                    run.roundtrip_ns >= run.service_ns,
                    "round trip {} shorter than service {}",
                    run.roundtrip_ns,
                    run.service_ns
                );
            }
            InferOutcome::Failed { dead, .. } => panic!("traced inference failed ({dead:?})"),
        }
        let dumps = pc.trace_dump();
        assert_eq!(dumps.len(), 3, "every daemon answers the dump");
        assert!(
            dumps.iter().any(|d| d.spans.iter().any(|s| s.trace_id == 77)),
            "no daemon recorded the traced inference"
        );
        pc.shutdown();
    }

    #[test]
    fn reinstall_after_exclusion_shrinks_and_reelects() {
        // daemons 5 and 9: banning 5 must re-elect 9 as leader and still
        // produce bit-identical outputs on the shrunken cluster
        let srv = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_secs(3)).unwrap();
        spawn_daemons(srv.addr(), &[5, 9]);
        let mut pc = ProcessCluster::connect(srv.addr(), 2, Duration::from_secs(10)).unwrap();

        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::OutC, model.n_layers());
        pc.install(&model, &plan, 7).unwrap();
        assert_eq!(pc.leader(), 5);

        pc.reinstall(Some(5)).unwrap();
        assert_eq!(pc.nodes(), 1);
        assert_eq!(pc.leader(), 9, "lowest surviving id must lead");

        let ws = WeightStore::for_model(&model, 7);
        let input = Tensor::random(16, 16, 3, 77);
        let reference = run_reference(&model, &ws, &input);
        match pc.infer(&input).unwrap() {
            InferOutcome::Done(run) => {
                assert_eq!(reference.max_abs_diff(&run.output), 0.0);
            }
            InferOutcome::Failed { dead, .. } => panic!("solo survivor failed (dead={dead:?})"),
        }
        pc.shutdown();
    }
}
