//! Wire codec: versioned length-prefixed binary frames.
//!
//! Every inter-node message — boundary tensor patches, scatter/gather,
//! control traffic (plan install, election, heartbeats, abort/drain), and
//! registry RPCs — serializes to one frame:
//!
//! ```text
//! offset  size  field        encoding
//! 0       4     magic        0x4658_5049 ("FXPI"), u32 LE
//! 4       2     version      u16 LE, currently 2 (1 still decodes)
//! 6       2     msg type     u16 LE, one discriminant per WireMsg variant
//! 8       4     sender node  u32 LE (CTL_NODE for the coordinator)
//! 12      8     term         u64 LE — plan generation; stale terms drop
//! 20      4     payload len  u32 LE, capped at MAX_PAYLOAD
//! 24      4     checksum     u32 LE, FNV-1a over the payload bytes
//! 28      —     payload      message-specific little-endian body
//! ```
//!
//! Version 2 appends trace context to the `Infer`/`Begin`/`Output`
//! payloads (a trace id, plus the daemon-measured service time on
//! `Output`). Decoding is version-aware: a v1 frame parses exactly as
//! before with the trace fields zeroed (0 = untraced), so old peers'
//! frames keep working — the fallback the codec tests pin down.
//!
//! All integers are explicit little-endian (`to_le_bytes`); floats travel as
//! their IEEE-754 bit patterns, so tensors survive the wire bit-exactly —
//! the property the process-mode e2e audit leans on. Malformed input of any
//! kind (bad magic, unknown version or type, truncated frame, oversized
//! length, checksum mismatch, inconsistent payload) surfaces as a typed
//! [`CodecError`], never a panic: a daemon must shrug off a corrupt or
//! hostile peer, not die with it.

use crate::compute::{RegionTensor, Tensor};
use crate::model::{ConvType, LayerMeta, Model, OpKind};
use crate::partition::{Mode, Plan, PlanStep, Region, Scheme};
use crate::trace::SpanRecord;

/// `"FXPI"` interpreted as a little-endian u32.
pub const MAGIC: u32 = 0x4658_5049;
/// Current wire protocol version (encodes trace context).
pub const VERSION: u16 = 2;
/// Oldest version this codec still decodes (no trace context).
pub const MIN_VERSION: u16 = 1;
/// Frame header size in bytes.
pub const HEADER_LEN: usize = 28;
/// Hard cap on payload size (64 MiB) — anything larger is rejected before
/// allocation, so a corrupt length field can't balloon memory.
pub const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;
/// Sender id the coordinator/registry uses in frame headers (daemons use
/// their registered node id).
pub const CTL_NODE: u32 = u32::MAX;

/// Typed decode failure. Every malformed-input path lands here.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    BadMagic(u32),
    BadVersion(u16),
    BadType(u16),
    /// Fewer bytes available than the frame declares.
    Truncated { need: usize, have: usize },
    Oversized { len: u32, max: u32 },
    BadChecksum { want: u32, got: u32 },
    /// Structurally valid frame whose payload doesn't parse.
    BadPayload(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::BadMagic(m) => write!(f, "bad magic {m:#010x}"),
            CodecError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            CodecError::BadType(t) => write!(f, "unknown message type {t}"),
            CodecError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            CodecError::Oversized { len, max } => {
                write!(f, "payload length {len} exceeds cap {max}")
            }
            CodecError::BadChecksum { want, got } => {
                write!(f, "checksum mismatch: header says {want:#010x}, payload hashes to {got:#010x}")
            }
            CodecError::BadPayload(msg) => write!(f, "bad payload: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// FNV-1a 32-bit over `data` — cheap, dependency-free integrity check.
pub fn fnv1a(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// One decoded frame: envelope (sender, term) plus the typed message.
#[derive(Debug, Clone)]
pub struct Frame {
    pub node: u32,
    pub term: u64,
    pub msg: WireMsg,
}

/// A registry row: where to reach one daemon.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistryEntry {
    pub node: u32,
    /// Control-plane address (coordinator dials this).
    pub ctl_addr: String,
    /// Data-plane address (peers dial this for boundary exchange).
    pub data_addr: String,
    /// Advertised capability (relative compute speed).
    pub speed: f64,
}

/// Every message the cluster moves, data plane and control plane alike.
#[derive(Debug, Clone)]
pub enum WireMsg {
    // --- data plane (peer <-> peer) ------------------------------------
    /// Connection handshake: sender identifies itself (id/term ride the
    /// header).
    Hello,
    /// Liveness beacon; also what mid-batch failure detection watches.
    Heartbeat,
    /// One boundary tensor patch of inference `seq` at exchange `boundary`.
    Patch { seq: u64, boundary: u32, patch: RegionTensor },

    // --- control plane (coordinator <-> daemon) ------------------------
    /// Install a plan generation: full model + plan + peer table, so a
    /// daemon needs no shared filesystem — weights re-derive from `seed`.
    PlanInstall {
        leader: u32,
        seed: u64,
        model: Model,
        plan: Plan,
        /// `(node id, data addr)` ordered by logical rank; a daemon finds
        /// its own rank by position.
        peers: Vec<(u32, String)>,
    },
    /// Leader announcement for the header's term.
    Elect { leader: u32 },
    /// Daemon ack: plan installed, data-plane mesh up for the header term.
    Ready,
    /// Drop in-flight work for the header's term.
    Abort,
    /// Finish in-flight work, accept no more.
    Drain,
    /// Coordinator -> leader: run inference `seq` on `input`. `trace` is
    /// the request's trace id (0 = untraced; absent on v1 frames).
    Infer { seq: u64, input: Tensor, trace: u64 },
    /// Coordinator -> worker: participate in inference `seq`.
    Begin { seq: u64, trace: u64 },
    /// Leader -> coordinator: gathered output plus traffic accounting.
    /// `trace` echoes the `Infer` trace id and `service_ns` reports the
    /// leader's measured compute wall time (both 0 on v1 frames).
    Output {
        seq: u64,
        output: Tensor,
        bytes: u64,
        msgs: u64,
        /// Per-boundary `(bytes, msgs)`.
        traffic: Vec<(u64, u64)>,
        trace: u64,
        service_ns: u64,
    },
    /// Leader -> coordinator: inference `seq` failed because `node` died.
    Failed { seq: u64, node: u32 },
    /// Daemon exits cleanly.
    Shutdown,

    // --- registry RPCs --------------------------------------------------
    /// Daemon -> registry: announce addresses and capabilities.
    Register { ctl_addr: String, data_addr: String, speed: f64 },
    RegisterOk { ttl_ms: u64 },
    /// Daemon -> registry: TTL renewal for the header's node id.
    Renew,
    RenewOk,
    /// Anyone -> registry: fetch the live (unexpired) peer set.
    Resolve,
    ResolveOk { entries: Vec<RegistryEntry> },

    // --- open-loop front door (load client <-> serving process) ---------
    /// Client -> front door: admit inference `seq` (client-scoped sequence
    /// number; the reply quotes it back).
    Submit { seq: u64, input: Tensor },
    /// Front door -> client: completed inference for `seq`.
    Reply { seq: u64, output: Tensor },
    /// Front door -> client: `seq` was not served. `reason` 0 = admission
    /// queue full (backpressure — retryable), 1 = server stopped, 2 =
    /// failed after admission (shutdown drain or exhausted replay budget).
    Denied { seq: u64, reason: u8 },

    // --- observability (coordinator <-> daemon) -------------------------
    /// Coordinator -> daemon: ship your flight recorder + resource usage.
    TraceDump,
    /// Daemon -> coordinator: drained spans plus the daemon's RSS gauge
    /// and CPU-time delta since daemon boot (0s when `/proc` is absent).
    TraceData { spans: Vec<SpanRecord>, rss_bytes: u64, cpu_ms: u64 },
}

impl WireMsg {
    /// Wire discriminant for the header's msg-type field.
    pub fn kind(&self) -> u16 {
        match self {
            WireMsg::Hello => 1,
            WireMsg::Heartbeat => 2,
            WireMsg::Patch { .. } => 3,
            WireMsg::PlanInstall { .. } => 4,
            WireMsg::Elect { .. } => 5,
            WireMsg::Ready => 6,
            WireMsg::Abort => 7,
            WireMsg::Drain => 8,
            WireMsg::Infer { .. } => 9,
            WireMsg::Begin { .. } => 10,
            WireMsg::Output { .. } => 11,
            WireMsg::Failed { .. } => 12,
            WireMsg::Shutdown => 13,
            WireMsg::Register { .. } => 14,
            WireMsg::RegisterOk { .. } => 15,
            WireMsg::Renew => 16,
            WireMsg::RenewOk => 17,
            WireMsg::Resolve => 18,
            WireMsg::ResolveOk { .. } => 19,
            WireMsg::Submit { .. } => 20,
            WireMsg::Reply { .. } => 21,
            WireMsg::Denied { .. } => 22,
            WireMsg::TraceDump => 23,
            WireMsg::TraceData { .. } => 24,
        }
    }
}

// --- little-endian payload writer/reader --------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        let bytes = s.as_bytes();
        assert!(bytes.len() <= u16::MAX as usize, "string too long for wire");
        self.u16(bytes.len() as u16);
        self.buf.extend_from_slice(bytes);
    }
    fn region(&mut self, r: &Region) {
        self.i64(r.h0);
        self.i64(r.h1);
        self.i64(r.w0);
        self.i64(r.w1);
        self.i64(r.c0);
        self.i64(r.c1);
    }
    fn tensor(&mut self, t: &Tensor) {
        self.i64(t.h);
        self.i64(t.w);
        self.i64(t.c);
        for &v in &t.data {
            self.f32(v);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.buf.len() {
            return Err(CodecError::BadPayload(format!(
                "payload underrun: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, CodecError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64, CodecError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Result<String, CodecError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| CodecError::BadPayload("string is not valid utf-8".into()))
    }
    fn region(&mut self) -> Result<Region, CodecError> {
        Ok(Region::new(
            self.i64()?,
            self.i64()?,
            self.i64()?,
            self.i64()?,
            self.i64()?,
            self.i64()?,
        ))
    }
    fn tensor(&mut self) -> Result<Tensor, CodecError> {
        let h = self.i64()?;
        let w = self.i64()?;
        let c = self.i64()?;
        if h < 0 || w < 0 || c < 0 {
            return Err(CodecError::BadPayload(format!("negative tensor dims {h}x{w}x{c}")));
        }
        let numel = h
            .checked_mul(w)
            .and_then(|v| v.checked_mul(c))
            .filter(|&v| v <= MAX_PAYLOAD as i64 / 4)
            .ok_or_else(|| {
                CodecError::BadPayload(format!("tensor dims {h}x{w}x{c} overflow the wire cap"))
            })? as usize;
        if numel * 4 > self.buf.len() - self.pos {
            return Err(CodecError::BadPayload(format!(
                "tensor claims {numel} elements, payload has {} bytes left",
                self.buf.len() - self.pos
            )));
        }
        let mut t = Tensor::zeros(h, w, c);
        for v in t.data.iter_mut() {
            *v = self.f32()?;
        }
        Ok(t)
    }
    fn done(&self) -> Result<(), CodecError> {
        if self.pos != self.buf.len() {
            return Err(CodecError::BadPayload(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// --- enum <-> u8 codes ---------------------------------------------------

fn scheme_code(s: Scheme) -> u8 {
    match s {
        Scheme::InH => 0,
        Scheme::InW => 1,
        Scheme::OutC => 2,
        Scheme::Grid2d => 3,
    }
}

fn scheme_from(code: u8) -> Result<Scheme, CodecError> {
    Ok(match code {
        0 => Scheme::InH,
        1 => Scheme::InW,
        2 => Scheme::OutC,
        3 => Scheme::Grid2d,
        _ => return Err(CodecError::BadPayload(format!("unknown scheme code {code}"))),
    })
}

fn mode_code(m: Mode) -> u8 {
    match m {
        Mode::T => 0,
        Mode::NT => 1,
    }
}

fn mode_from(code: u8) -> Result<Mode, CodecError> {
    Ok(match code {
        0 => Mode::T,
        1 => Mode::NT,
        _ => return Err(CodecError::BadPayload(format!("unknown mode code {code}"))),
    })
}

fn conv_code(c: ConvType) -> u8 {
    match c {
        ConvType::Standard => 0,
        ConvType::Depthwise => 1,
        ConvType::Pointwise => 2,
        ConvType::Dense => 3,
        ConvType::Attention => 4,
        ConvType::Pool => 5,
    }
}

fn conv_from(code: u8) -> Result<ConvType, CodecError> {
    Ok(match code {
        0 => ConvType::Standard,
        1 => ConvType::Depthwise,
        2 => ConvType::Pointwise,
        3 => ConvType::Dense,
        4 => ConvType::Attention,
        5 => ConvType::Pool,
        _ => return Err(CodecError::BadPayload(format!("unknown conv type code {code}"))),
    })
}

fn op_code(o: OpKind) -> u8 {
    match o {
        OpKind::Conv => 0,
        OpKind::Pool => 1,
        OpKind::MatMul => 2,
    }
}

fn op_from(code: u8) -> Result<OpKind, CodecError> {
    Ok(match code {
        0 => OpKind::Conv,
        1 => OpKind::Pool,
        2 => OpKind::MatMul,
        _ => return Err(CodecError::BadPayload(format!("unknown op code {code}"))),
    })
}

fn write_layer(w: &mut Writer, l: &LayerMeta) {
    w.str(&l.name);
    w.u8(op_code(l.op));
    w.u8(conv_code(l.conv_t));
    for v in [l.in_h, l.in_w, l.in_c, l.out_h, l.out_w, l.out_c, l.k, l.s, l.p] {
        w.i64(v);
    }
    w.u8(l.fused_residual as u8);
    w.u8(l.fused_activation as u8);
}

fn read_layer(r: &mut Reader) -> Result<LayerMeta, CodecError> {
    let name = r.str()?;
    let op = op_from(r.u8()?)?;
    let conv_t = conv_from(r.u8()?)?;
    let mut dims = [0i64; 9];
    for d in dims.iter_mut() {
        *d = r.i64()?;
    }
    let fused_residual = r.u8()? != 0;
    let fused_activation = r.u8()? != 0;
    Ok(LayerMeta {
        name,
        op,
        conv_t,
        in_h: dims[0],
        in_w: dims[1],
        in_c: dims[2],
        out_h: dims[3],
        out_w: dims[4],
        out_c: dims[5],
        k: dims[6],
        s: dims[7],
        p: dims[8],
        fused_residual,
        fused_activation,
    })
}

fn write_model(w: &mut Writer, m: &Model) {
    w.str(&m.name);
    w.u32(m.layers.len() as u32);
    for l in &m.layers {
        write_layer(w, l);
    }
}

fn read_model(r: &mut Reader) -> Result<Model, CodecError> {
    let name = r.str()?;
    let n = r.u32()? as usize;
    let mut layers = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        layers.push(read_layer(r)?);
    }
    let m = Model { name, layers };
    m.validate().map_err(CodecError::BadPayload)?;
    Ok(m)
}

fn write_plan(w: &mut Writer, p: &Plan) {
    w.u32(p.steps.len() as u32);
    for st in &p.steps {
        w.u8(scheme_code(st.scheme));
        w.u8(mode_code(st.mode));
    }
    w.f64(p.est_cost);
}

fn read_plan(r: &mut Reader) -> Result<Plan, CodecError> {
    let n = r.u32()? as usize;
    let mut steps = Vec::with_capacity(n.min(65536));
    for _ in 0..n {
        let scheme = scheme_from(r.u8()?)?;
        let mode = mode_from(r.u8()?)?;
        steps.push(PlanStep { scheme, mode });
    }
    let est_cost = r.f64()?;
    let p = Plan { steps, est_cost };
    p.validate().map_err(CodecError::BadPayload)?;
    Ok(p)
}

// --- frame encode/decode -------------------------------------------------

fn encode_payload(msg: &WireMsg) -> Vec<u8> {
    let mut w = Writer::new();
    match msg {
        WireMsg::Hello
        | WireMsg::Heartbeat
        | WireMsg::Ready
        | WireMsg::Abort
        | WireMsg::Drain
        | WireMsg::Shutdown
        | WireMsg::Renew
        | WireMsg::RenewOk
        | WireMsg::Resolve => {}
        WireMsg::Patch { seq, boundary, patch } => {
            w.u64(*seq);
            w.u32(*boundary);
            w.region(&patch.region);
            w.tensor(&patch.t);
        }
        WireMsg::PlanInstall { leader, seed, model, plan, peers } => {
            w.u32(*leader);
            w.u64(*seed);
            write_model(&mut w, model);
            write_plan(&mut w, plan);
            w.u32(peers.len() as u32);
            for (id, addr) in peers {
                w.u32(*id);
                w.str(addr);
            }
        }
        WireMsg::Elect { leader } => w.u32(*leader),
        WireMsg::Infer { seq, input, trace } => {
            w.u64(*seq);
            w.tensor(input);
            w.u64(*trace);
        }
        WireMsg::Begin { seq, trace } => {
            w.u64(*seq);
            w.u64(*trace);
        }
        WireMsg::Output { seq, output, bytes, msgs, traffic, trace, service_ns } => {
            w.u64(*seq);
            w.tensor(output);
            w.u64(*bytes);
            w.u64(*msgs);
            w.u32(traffic.len() as u32);
            for (b, m) in traffic {
                w.u64(*b);
                w.u64(*m);
            }
            w.u64(*trace);
            w.u64(*service_ns);
        }
        WireMsg::Failed { seq, node } => {
            w.u64(*seq);
            w.u32(*node);
        }
        WireMsg::Register { ctl_addr, data_addr, speed } => {
            w.str(ctl_addr);
            w.str(data_addr);
            w.f64(*speed);
        }
        WireMsg::RegisterOk { ttl_ms } => w.u64(*ttl_ms),
        WireMsg::ResolveOk { entries } => {
            w.u32(entries.len() as u32);
            for e in entries {
                w.u32(e.node);
                w.str(&e.ctl_addr);
                w.str(&e.data_addr);
                w.f64(e.speed);
            }
        }
        WireMsg::Submit { seq, input } => {
            w.u64(*seq);
            w.tensor(input);
        }
        WireMsg::Reply { seq, output } => {
            w.u64(*seq);
            w.tensor(output);
        }
        WireMsg::Denied { seq, reason } => {
            w.u64(*seq);
            w.u8(*reason);
        }
        WireMsg::TraceDump => {}
        WireMsg::TraceData { spans, rss_bytes, cpu_ms } => {
            w.u32(spans.len() as u32);
            for s in spans {
                w.u64(s.trace_id);
                w.u64(s.gen);
                w.u8(s.kind);
                w.u32(s.node);
                w.u64(s.start_ns);
                w.u64(s.dur_ns);
            }
            w.u64(*rss_bytes);
            w.u64(*cpu_ms);
        }
    }
    w.buf
}

fn decode_payload(version: u16, kind: u16, payload: &[u8]) -> Result<WireMsg, CodecError> {
    let mut r = Reader::new(payload);
    // v1 peers never wrote trace context; read it only on v2+ frames so
    // old frames keep parsing byte-for-byte (decode fallback).
    let traced = version >= 2;
    let msg = match kind {
        1 => WireMsg::Hello,
        2 => WireMsg::Heartbeat,
        3 => {
            let seq = r.u64()?;
            let boundary = r.u32()?;
            let region = r.region()?;
            let t = r.tensor()?;
            let (eh, ew, ec) =
                (region.h1 - region.h0, region.w1 - region.w0, region.c1 - region.c0);
            if (t.h, t.w, t.c) != (eh, ew, ec) {
                return Err(CodecError::BadPayload(format!(
                    "patch tensor {}x{}x{} does not match region extent {eh}x{ew}x{ec}",
                    t.h, t.w, t.c
                )));
            }
            WireMsg::Patch { seq, boundary, patch: RegionTensor::new(region, t) }
        }
        4 => {
            let leader = r.u32()?;
            let seed = r.u64()?;
            let model = read_model(&mut r)?;
            let plan = read_plan(&mut r)?;
            if plan.steps.len() != model.layers.len() {
                return Err(CodecError::BadPayload(format!(
                    "plan has {} steps for a {}-layer model",
                    plan.steps.len(),
                    model.layers.len()
                )));
            }
            let n = r.u32()? as usize;
            let mut peers = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let id = r.u32()?;
                let addr = r.str()?;
                peers.push((id, addr));
            }
            WireMsg::PlanInstall { leader, seed, model, plan, peers }
        }
        5 => WireMsg::Elect { leader: r.u32()? },
        6 => WireMsg::Ready,
        7 => WireMsg::Abort,
        8 => WireMsg::Drain,
        9 => {
            let seq = r.u64()?;
            let input = r.tensor()?;
            let trace = if traced { r.u64()? } else { 0 };
            WireMsg::Infer { seq, input, trace }
        }
        10 => {
            let seq = r.u64()?;
            let trace = if traced { r.u64()? } else { 0 };
            WireMsg::Begin { seq, trace }
        }
        11 => {
            let seq = r.u64()?;
            let output = r.tensor()?;
            let bytes = r.u64()?;
            let msgs = r.u64()?;
            let n = r.u32()? as usize;
            let mut traffic = Vec::with_capacity(n.min(65536));
            for _ in 0..n {
                let b = r.u64()?;
                let m = r.u64()?;
                traffic.push((b, m));
            }
            let (trace, service_ns) =
                if traced { (r.u64()?, r.u64()?) } else { (0, 0) };
            WireMsg::Output { seq, output, bytes, msgs, traffic, trace, service_ns }
        }
        12 => {
            let seq = r.u64()?;
            let node = r.u32()?;
            WireMsg::Failed { seq, node }
        }
        13 => WireMsg::Shutdown,
        14 => {
            let ctl_addr = r.str()?;
            let data_addr = r.str()?;
            let speed = r.f64()?;
            WireMsg::Register { ctl_addr, data_addr, speed }
        }
        15 => WireMsg::RegisterOk { ttl_ms: r.u64()? },
        16 => WireMsg::Renew,
        17 => WireMsg::RenewOk,
        18 => WireMsg::Resolve,
        19 => {
            let n = r.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let node = r.u32()?;
                let ctl_addr = r.str()?;
                let data_addr = r.str()?;
                let speed = r.f64()?;
                entries.push(RegistryEntry { node, ctl_addr, data_addr, speed });
            }
            WireMsg::ResolveOk { entries }
        }
        20 => {
            let seq = r.u64()?;
            let input = r.tensor()?;
            WireMsg::Submit { seq, input }
        }
        21 => {
            let seq = r.u64()?;
            let output = r.tensor()?;
            WireMsg::Reply { seq, output }
        }
        22 => {
            let seq = r.u64()?;
            let reason = r.u8()?;
            WireMsg::Denied { seq, reason }
        }
        23 => WireMsg::TraceDump,
        24 => {
            let n = r.u32()? as usize;
            let mut spans = Vec::with_capacity(n.min(65536));
            for _ in 0..n {
                spans.push(SpanRecord {
                    trace_id: r.u64()?,
                    gen: r.u64()?,
                    kind: r.u8()?,
                    node: r.u32()?,
                    start_ns: r.u64()?,
                    dur_ns: r.u64()?,
                });
            }
            let rss_bytes = r.u64()?;
            let cpu_ms = r.u64()?;
            WireMsg::TraceData { spans, rss_bytes, cpu_ms }
        }
        other => return Err(CodecError::BadType(other)),
    };
    r.done()?;
    Ok(msg)
}

/// Encode one frame to bytes (header + payload).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let payload = encode_payload(&frame.msg);
    assert!(payload.len() as u32 <= MAX_PAYLOAD, "payload exceeds wire cap");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&frame.msg.kind().to_le_bytes());
    out.extend_from_slice(&frame.node.to_le_bytes());
    out.extend_from_slice(&frame.term.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Validated frame header, parsed but with the payload still unread —
/// the streaming path (`tcp`) reads `payload_len` more bytes, then calls
/// [`decode_body`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    /// Negotiated wire version (`MIN_VERSION..=VERSION`); payload decoding
    /// is version-aware.
    pub version: u16,
    pub msg_type: u16,
    pub node: u32,
    pub term: u64,
    pub payload_len: u32,
    pub checksum: u32,
}

/// Parse and validate the fixed 28-byte header.
pub fn decode_header(buf: &[u8]) -> Result<Header, CodecError> {
    if buf.len() < HEADER_LEN {
        return Err(CodecError::Truncated { need: HEADER_LEN, have: buf.len() });
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(CodecError::BadVersion(version));
    }
    let msg_type = u16::from_le_bytes(buf[6..8].try_into().unwrap());
    let node = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let term = u64::from_le_bytes(buf[12..20].try_into().unwrap());
    let payload_len = u32::from_le_bytes(buf[20..24].try_into().unwrap());
    if payload_len > MAX_PAYLOAD {
        return Err(CodecError::Oversized { len: payload_len, max: MAX_PAYLOAD });
    }
    let checksum = u32::from_le_bytes(buf[24..28].try_into().unwrap());
    Ok(Header { version, msg_type, node, term, payload_len, checksum })
}

/// Verify the checksum and decode the payload against a parsed header.
pub fn decode_body(h: &Header, payload: &[u8]) -> Result<Frame, CodecError> {
    if payload.len() != h.payload_len as usize {
        return Err(CodecError::Truncated {
            need: h.payload_len as usize,
            have: payload.len(),
        });
    }
    let got = fnv1a(payload);
    if got != h.checksum {
        return Err(CodecError::BadChecksum { want: h.checksum, got });
    }
    let msg = decode_payload(h.version, h.msg_type, payload)?;
    Ok(Frame { node: h.node, term: h.term, msg })
}

/// Decode one frame from a buffer; returns the frame and bytes consumed.
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), CodecError> {
    let h = decode_header(buf)?;
    let total = HEADER_LEN + h.payload_len as usize;
    if buf.len() < total {
        return Err(CodecError::Truncated { need: total, have: buf.len() });
    }
    let frame = decode_body(&h, &buf[HEADER_LEN..total])?;
    Ok((frame, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn sample_frames() -> Vec<Frame> {
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let region = Region::new(0, 2, 0, 3, 0, 1);
        let t = Tensor::random(2, 3, 1, 7);
        let patch = RegionTensor::new(region, t.clone());
        vec![
            Frame { node: 0, term: 1, msg: WireMsg::Hello },
            Frame { node: 3, term: 9, msg: WireMsg::Heartbeat },
            Frame { node: 1, term: 2, msg: WireMsg::Patch { seq: 5, boundary: 3, patch } },
            Frame {
                node: CTL_NODE,
                term: 4,
                msg: WireMsg::PlanInstall {
                    leader: 0,
                    seed: 11,
                    model,
                    plan,
                    peers: vec![
                        (0, "tcp:127.0.0.1:4000".into()),
                        (1, "tcp:127.0.0.1:4001".into()),
                        (2, "unix:/tmp/flexpie-2.sock".into()),
                    ],
                },
            },
            Frame { node: CTL_NODE, term: 4, msg: WireMsg::Elect { leader: 2 } },
            Frame { node: 2, term: 4, msg: WireMsg::Ready },
            Frame { node: CTL_NODE, term: 4, msg: WireMsg::Abort },
            Frame { node: CTL_NODE, term: 4, msg: WireMsg::Drain },
            Frame {
                node: CTL_NODE,
                term: 4,
                msg: WireMsg::Infer { seq: 42, input: t.clone(), trace: 901 },
            },
            Frame { node: CTL_NODE, term: 4, msg: WireMsg::Begin { seq: 42, trace: 901 } },
            Frame {
                node: 0,
                term: 4,
                msg: WireMsg::Output {
                    seq: 42,
                    output: t,
                    bytes: 1024,
                    msgs: 7,
                    traffic: vec![(512, 3), (512, 4)],
                    trace: 901,
                    service_ns: 2_500_000,
                },
            },
            Frame { node: 0, term: 4, msg: WireMsg::Failed { seq: 43, node: 2 } },
            Frame { node: 1, term: 0, msg: WireMsg::Shutdown },
            Frame {
                node: 1,
                term: 0,
                msg: WireMsg::Register {
                    ctl_addr: "tcp:127.0.0.1:5001".into(),
                    data_addr: "tcp:127.0.0.1:6001".into(),
                    speed: 1.5,
                },
            },
            Frame { node: CTL_NODE, term: 0, msg: WireMsg::RegisterOk { ttl_ms: 1500 } },
            Frame { node: 1, term: 0, msg: WireMsg::Renew },
            Frame { node: CTL_NODE, term: 0, msg: WireMsg::RenewOk },
            Frame { node: CTL_NODE, term: 0, msg: WireMsg::Resolve },
            Frame {
                node: CTL_NODE,
                term: 0,
                msg: WireMsg::ResolveOk {
                    entries: vec![RegistryEntry {
                        node: 1,
                        ctl_addr: "tcp:127.0.0.1:5001".into(),
                        data_addr: "tcp:127.0.0.1:6001".into(),
                        speed: 1.5,
                    }],
                },
            },
            Frame {
                node: 7,
                term: 0,
                msg: WireMsg::Submit { seq: 3, input: Tensor::random(2, 3, 1, 8) },
            },
            Frame {
                node: CTL_NODE,
                term: 0,
                msg: WireMsg::Reply { seq: 3, output: Tensor::random(1, 1, 4, 9) },
            },
            Frame { node: CTL_NODE, term: 0, msg: WireMsg::Denied { seq: 4, reason: 1 } },
            Frame { node: CTL_NODE, term: 4, msg: WireMsg::TraceDump },
            Frame {
                node: 2,
                term: 4,
                msg: WireMsg::TraceData {
                    spans: vec![
                        crate::trace::SpanRecord {
                            trace_id: 901,
                            gen: 4,
                            kind: crate::trace::KIND_SERVICE,
                            node: 2,
                            start_ns: 1_000,
                            dur_ns: 2_500_000,
                        },
                        crate::trace::SpanRecord {
                            trace_id: 902,
                            gen: 4,
                            kind: crate::trace::KIND_STAGE,
                            node: 1,
                            start_ns: 9_000,
                            dur_ns: 700_000,
                        },
                    ],
                    rss_bytes: 8 << 20,
                    cpu_ms: 120,
                },
            },
        ]
    }

    #[test]
    fn every_message_type_round_trips() {
        let frames = sample_frames();
        // one frame per wire discriminant — a new variant without a sample
        // here fails this census
        let mut kinds: Vec<u16> = frames.iter().map(|f| f.msg.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds, (1u16..=24).collect::<Vec<_>>(), "sample set misses a msg type");
        for f in frames {
            let bytes = encode(&f);
            let (back, used) = decode(&bytes).expect("decode");
            assert_eq!(used, bytes.len());
            // decode → re-encode is byte-identical: field-exact round trip
            // (works even through NaN est_cost, where == would lie)
            assert_eq!(encode(&back), bytes, "re-encode differs for {:?}", f.msg.kind());
            assert_eq!(back.node, f.node);
            assert_eq!(back.term, f.term);
            assert_eq!(back.msg.kind(), f.msg.kind());
        }
    }

    #[test]
    fn tensors_survive_the_wire_bit_exactly() {
        let t = Tensor::random(8, 8, 3, 1234);
        let f = Frame {
            node: CTL_NODE,
            term: 1,
            msg: WireMsg::Infer { seq: 1, input: t.clone(), trace: 0 },
        };
        let (back, _) = decode(&encode(&f)).unwrap();
        match back.msg {
            WireMsg::Infer { input, .. } => assert_eq!(input.max_abs_diff(&t), 0.0),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn truncated_frames_reject_typed() {
        let f = Frame { node: 1, term: 2, msg: WireMsg::Begin { seq: 9, trace: 0 } };
        let bytes = encode(&f);
        // header cut short
        assert!(matches!(
            decode(&bytes[..HEADER_LEN - 1]),
            Err(CodecError::Truncated { need, have }) if need == HEADER_LEN && have == HEADER_LEN - 1
        ));
        // payload cut short
        assert!(matches!(
            decode(&bytes[..bytes.len() - 2]),
            Err(CodecError::Truncated { .. })
        ));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode(&Frame { node: 0, term: 0, msg: WireMsg::Hello });
        bytes[0] ^= 0xFF;
        assert!(matches!(decode(&bytes), Err(CodecError::BadMagic(_))));
    }

    #[test]
    fn wrong_version_rejected() {
        let mut bytes = encode(&Frame { node: 0, term: 0, msg: WireMsg::Hello });
        bytes[4] = 0xEE;
        assert!(matches!(decode(&bytes), Err(CodecError::BadVersion(_))));
    }

    #[test]
    fn unknown_type_rejected() {
        let mut bytes = encode(&Frame { node: 0, term: 0, msg: WireMsg::Hello });
        bytes[6] = 0xFF;
        bytes[7] = 0x7F;
        assert!(matches!(decode(&bytes), Err(CodecError::BadType(0x7FFF))));
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut bytes = encode(&Frame { node: 0, term: 0, msg: WireMsg::Hello });
        bytes[20..24].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(decode(&bytes), Err(CodecError::Oversized { .. })));
    }

    #[test]
    fn checksum_mismatch_rejected() {
        let f = Frame { node: 1, term: 2, msg: WireMsg::Begin { seq: 9, trace: 0 } };
        let mut bytes = encode(&f);
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01; // flip one payload bit
        assert!(matches!(decode(&bytes), Err(CodecError::BadChecksum { .. })));
    }

    #[test]
    fn garbage_payload_rejected_not_panicking() {
        // structurally valid frame whose payload contradicts itself: a Patch
        // whose region extent disagrees with the tensor dims
        let region = Region::new(0, 2, 0, 2, 0, 1);
        let t = Tensor::zeros(2, 2, 1);
        let good = encode(&Frame {
            node: 0,
            term: 0,
            msg: WireMsg::Patch { seq: 0, boundary: 0, patch: RegionTensor::new(region, t) },
        });
        // corrupt the region's h1 (first region field after seq+boundary)
        let mut bad = good.clone();
        let h1_off = HEADER_LEN + 8 + 4 + 8; // seq + boundary + h0
        bad[h1_off..h1_off + 8].copy_from_slice(&3i64.to_le_bytes());
        // re-stamp the checksum so only the payload semantics are wrong
        let payload = bad[HEADER_LEN..].to_vec();
        let sum = fnv1a(&payload);
        bad[24..28].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&bad), Err(CodecError::BadPayload(_))));
    }

    #[test]
    fn trailing_bytes_in_payload_rejected() {
        let mut bytes = encode(&Frame { node: 0, term: 0, msg: WireMsg::Renew });
        // declare one extra payload byte and supply it
        bytes[20..24].copy_from_slice(&1u32.to_le_bytes());
        bytes.push(0xAB);
        let sum = fnv1a(&[0xAB]);
        bytes[24..28].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(CodecError::BadPayload(_))));
    }

    /// Build a raw frame with an arbitrary version stamp — what a v1 peer
    /// would put on the wire.
    fn raw_frame(version: u16, kind: u16, node: u32, term: u64, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&kind.to_le_bytes());
        out.extend_from_slice(&node.to_le_bytes());
        out.extend_from_slice(&term.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&fnv1a(payload).to_le_bytes());
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn v1_frames_still_decode_without_trace_context() {
        // A v1 Begin payload is just the seq — no trace id field.
        let bytes = raw_frame(1, 10, CTL_NODE, 3, &9u64.to_le_bytes());
        let (f, used) = decode(&bytes).expect("v1 Begin must decode");
        assert_eq!(used, bytes.len());
        assert!(matches!(f.msg, WireMsg::Begin { seq: 9, trace: 0 }));

        // A v1 Infer payload: seq + tensor, nothing after.
        let t = Tensor::random(2, 2, 1, 5);
        let mut w = Writer::new();
        w.u64(42);
        w.tensor(&t);
        let bytes = raw_frame(1, 9, CTL_NODE, 3, &w.buf);
        let (f, _) = decode(&bytes).expect("v1 Infer must decode");
        match f.msg {
            WireMsg::Infer { seq, input, trace } => {
                assert_eq!((seq, trace), (42, 0));
                assert_eq!(input.max_abs_diff(&t), 0.0);
            }
            other => panic!("wrong variant {other:?}"),
        }

        // A v1 Output payload ends after the traffic vector.
        let mut w = Writer::new();
        w.u64(42);
        w.tensor(&t);
        w.u64(100);
        w.u64(2);
        w.u32(1);
        w.u64(100);
        w.u64(2);
        let bytes = raw_frame(1, 11, 0, 3, &w.buf);
        let (f, _) = decode(&bytes).expect("v1 Output must decode");
        match f.msg {
            WireMsg::Output { seq, trace, service_ns, .. } => {
                assert_eq!((seq, trace, service_ns), (42, 0, 0));
            }
            other => panic!("wrong variant {other:?}"),
        }

        // The same v1 payloads under a v2 stamp are *rejected* (missing
        // trace fields), not misread — trailing-byte discipline holds both
        // ways.
        let bytes = raw_frame(2, 10, CTL_NODE, 3, &9u64.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(CodecError::BadPayload(_))));
    }

    #[test]
    fn fnv1a_known_vectors() {
        // standard FNV-1a 32-bit test vectors
        assert_eq!(fnv1a(b""), 0x811c_9dc5);
        assert_eq!(fnv1a(b"a"), 0xe40c_292c);
        assert_eq!(fnv1a(b"foobar"), 0xbf9c_f968);
    }
}
