//! Wire transport: the cluster's two interchangeable message fabrics.
//!
//! PRs 1–5 ran every "distributed" inference as threads in one process,
//! exchanging boundary tensors over in-memory channels. That simulated
//! fabric stays — it is the deterministic test/CI mode — but this module
//! adds the real one: **one OS process per node**, talking length-prefixed
//! binary frames ([`codec`]) over TCP (or Unix domain sockets where
//! available), discovering each other through a TTL [`registry`], and
//! dying for real under `kill -9`.
//!
//! The seam between the two worlds is the [`Exchange`] trait: the lockstep
//! protocol in [`crate::cluster`] is generic over it, so the exact same
//! `node_main` byte-for-byte protocol runs on either fabric. The simulated
//! backend ([`crate::cluster::SimExchange`]) implements it over mpsc
//! channels; [`tcp::TcpExchange`] implements it over sockets with
//! connect/accept retry + backoff, per-peer deadlines, and heartbeat-based
//! mid-batch failure detection.
//!
//! Process-mode topology (mirrors the paper's testbed of discrete devices):
//!
//! ```text
//!   flexpie-ctl (coordinator)          flexpie-ctl registry
//!      │  PlanInstall/Infer/Begin          ▲ Register/Renew/Resolve (TTL)
//!      ▼                                   │
//!   flexpie-node 0 ◄──boundary──► flexpie-node 1 ◄──► flexpie-node 2
//!      (leader: scatter/gather)     (worker)            (worker)
//! ```

pub mod codec;
pub mod coord;
pub mod daemon;
pub mod fault;
pub mod registry;
pub mod tcp;

use std::time::Duration;

use crate::compute::{PatchStore, RegionTensor};

/// Why an exchange operation failed. The lockstep protocol treats any of
/// these as "this inference cannot complete on the current cluster" — the
/// caller reports an explicit failure (never a silent drop) and the
/// election/failover path takes over.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportError {
    /// A peer is gone: its connection broke or its heartbeats stopped.
    PeerDead(usize),
    /// Waited past the recv deadline with no verdict on any one peer.
    Deadline { boundary: usize, got: usize, expect: usize },
    /// A peer sent bytes that don't decode.
    Codec(codec::CodecError),
    /// Socket-level failure.
    Io(String),
    /// A well-formed message that violates the lockstep protocol.
    Protocol(String),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::PeerDead(n) => write!(f, "peer {n} is dead"),
            TransportError::Deadline { boundary, got, expect } => write!(
                f,
                "recv deadline at boundary {boundary}: got {got}/{expect} patches"
            ),
            TransportError::Codec(e) => write!(f, "codec: {e}"),
            TransportError::Io(e) => write!(f, "io: {e}"),
            TransportError::Protocol(e) => write!(f, "protocol: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<codec::CodecError> for TransportError {
    fn from(e: codec::CodecError) -> Self {
        TransportError::Codec(e)
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e.to_string())
    }
}

/// One node's view of the boundary-exchange fabric. Implementations carry
/// the node's identity; `to` is a logical rank on the current (compacted)
/// cluster. `recv_for` must deliver **exactly** `expect` patches tagged
/// `boundary` into `store`, buffering any patches that arrive early for
/// later boundaries (a fast peer may run ahead) — or return a typed error
/// when a peer's death or a deadline makes that impossible. Death must
/// surface *mid-wait*, not only at batch boundaries: both backends watch
/// liveness while blocked.
pub trait Exchange {
    fn send(
        &mut self,
        to: usize,
        boundary: usize,
        patch: RegionTensor,
    ) -> Result<(), TransportError>;

    fn recv_for(
        &mut self,
        boundary: usize,
        expect: usize,
        store: &mut PatchStore,
    ) -> Result<(), TransportError>;
}

/// The one retry/timeout/backoff policy for control-plane calls — registry
/// RPCs, coordinator dials, daemon boot registration. Before PR 7 every
/// call site hard-coded its own constants (a 5 s dial here, a 5 s RPC
/// deadline there, no retries anywhere); now they all run through
/// [`RetryPolicy::run`], so timeouts are tuned in one place and transient
/// unreachability (a registry that comes up a beat after its daemons, a
/// peer mid-restart) is absorbed instead of fatal.
///
/// Backoff doubles from `base_backoff` up to `max_backoff`, with
/// deterministic jitter: the jitter stream is seeded by
/// `seed ^ fnv1a(label)`, so a given call site retries at reproducible
/// offsets (replayable in tests) while distinct call sites desynchronize.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts (first try included). Clamped to at least 1.
    pub attempts: u32,
    /// Sleep before the first retry; doubles per retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Per-attempt deadline, handed to whatever dial/roundtrip the
    /// attempt performs.
    pub deadline: Duration,
    /// Jitter seed (combined with the call-site label).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 3,
            base_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_millis(250),
            deadline: Duration::from_secs(2),
            seed: 0x7e11_ab1e,
        }
    }
}

impl RetryPolicy {
    /// Run `op` up to `attempts` times, sleeping a jittered, doubling
    /// backoff between attempts. `op` receives the attempt index (0-based)
    /// and should bound its own blocking by [`RetryPolicy::deadline`].
    /// Returns the first success, or the last error once attempts are
    /// exhausted.
    pub fn run<T>(
        &self,
        label: &str,
        mut op: impl FnMut(u32) -> Result<T, TransportError>,
    ) -> Result<T, TransportError> {
        let attempts = self.attempts.max(1);
        let mut rng = crate::util::rng::Rng::new(self.seed ^ codec::fnv1a(label.as_bytes()) as u64);
        let mut backoff = self.base_backoff;
        let mut last = TransportError::Protocol(format!("{label}: no attempt ran"));
        for attempt in 0..attempts {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => last = e,
            }
            if attempt + 1 < attempts {
                std::thread::sleep(backoff.mul_f64(rng.range_f64(0.5, 1.5)));
                backoff = (backoff * 2).min(self.max_backoff);
            }
        }
        Err(last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_policy() -> RetryPolicy {
        RetryPolicy {
            attempts: 4,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(4),
            deadline: Duration::from_millis(50),
            ..RetryPolicy::default()
        }
    }

    #[test]
    fn retry_succeeds_after_transient_failures() {
        let mut calls = 0u32;
        let out = fast_policy().run("test-rpc", |attempt| {
            calls += 1;
            assert_eq!(attempt, calls - 1);
            if attempt < 2 {
                Err(TransportError::Io("connection refused".into()))
            } else {
                Ok(attempt)
            }
        });
        assert_eq!(out, Ok(2));
        assert_eq!(calls, 3, "succeeded on the third attempt, then stopped");
    }

    #[test]
    fn retry_exhaustion_returns_the_last_error() {
        let mut calls = 0u32;
        let out: Result<(), _> = fast_policy().run("test-rpc", |attempt| {
            calls += 1;
            Err(TransportError::Io(format!("refused on attempt {attempt}")))
        });
        assert_eq!(calls, 4, "all attempts consumed");
        assert_eq!(out, Err(TransportError::Io("refused on attempt 3".into())));
    }

    #[test]
    fn zero_attempts_still_runs_once() {
        let mut calls = 0u32;
        let out = RetryPolicy { attempts: 0, ..fast_policy() }.run("test-rpc", |_| {
            calls += 1;
            Ok(7u32)
        });
        assert_eq!(out, Ok(7));
        assert_eq!(calls, 1);
    }

    #[test]
    fn jitter_is_deterministic_per_label() {
        // same seed + label → identical jitter stream; different labels
        // desynchronize. Probe the stream directly rather than timing
        // sleeps (wall-clock assertions flake under CI load).
        let p = RetryPolicy::default();
        let stream = |label: &str| {
            let mut rng =
                crate::util::rng::Rng::new(p.seed ^ codec::fnv1a(label.as_bytes()) as u64);
            (0..4).map(|_| rng.range_f64(0.5, 1.5)).collect::<Vec<_>>()
        };
        assert_eq!(stream("registry.register"), stream("registry.register"));
        assert_ne!(stream("registry.register"), stream("coord.dial"));
        for j in stream("registry.register") {
            assert!((0.5..1.5).contains(&j));
        }
    }
}
