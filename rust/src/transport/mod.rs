//! Wire transport: the cluster's two interchangeable message fabrics.
//!
//! PRs 1–5 ran every "distributed" inference as threads in one process,
//! exchanging boundary tensors over in-memory channels. That simulated
//! fabric stays — it is the deterministic test/CI mode — but this module
//! adds the real one: **one OS process per node**, talking length-prefixed
//! binary frames ([`codec`]) over TCP (or Unix domain sockets where
//! available), discovering each other through a TTL [`registry`], and
//! dying for real under `kill -9`.
//!
//! The seam between the two worlds is the [`Exchange`] trait: the lockstep
//! protocol in [`crate::cluster`] is generic over it, so the exact same
//! `node_main` byte-for-byte protocol runs on either fabric. The simulated
//! backend ([`crate::cluster::SimExchange`]) implements it over mpsc
//! channels; [`tcp::TcpExchange`] implements it over sockets with
//! connect/accept retry + backoff, per-peer deadlines, and heartbeat-based
//! mid-batch failure detection.
//!
//! Process-mode topology (mirrors the paper's testbed of discrete devices):
//!
//! ```text
//!   flexpie-ctl (coordinator)          flexpie-ctl registry
//!      │  PlanInstall/Infer/Begin          ▲ Register/Renew/Resolve (TTL)
//!      ▼                                   │
//!   flexpie-node 0 ◄──boundary──► flexpie-node 1 ◄──► flexpie-node 2
//!      (leader: scatter/gather)     (worker)            (worker)
//! ```

pub mod codec;
pub mod coord;
pub mod daemon;
pub mod registry;
pub mod tcp;

use crate::compute::{PatchStore, RegionTensor};

/// Why an exchange operation failed. The lockstep protocol treats any of
/// these as "this inference cannot complete on the current cluster" — the
/// caller reports an explicit failure (never a silent drop) and the
/// election/failover path takes over.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportError {
    /// A peer is gone: its connection broke or its heartbeats stopped.
    PeerDead(usize),
    /// Waited past the recv deadline with no verdict on any one peer.
    Deadline { boundary: usize, got: usize, expect: usize },
    /// A peer sent bytes that don't decode.
    Codec(codec::CodecError),
    /// Socket-level failure.
    Io(String),
    /// A well-formed message that violates the lockstep protocol.
    Protocol(String),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::PeerDead(n) => write!(f, "peer {n} is dead"),
            TransportError::Deadline { boundary, got, expect } => write!(
                f,
                "recv deadline at boundary {boundary}: got {got}/{expect} patches"
            ),
            TransportError::Codec(e) => write!(f, "codec: {e}"),
            TransportError::Io(e) => write!(f, "io: {e}"),
            TransportError::Protocol(e) => write!(f, "protocol: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<codec::CodecError> for TransportError {
    fn from(e: codec::CodecError) -> Self {
        TransportError::Codec(e)
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e.to_string())
    }
}

/// One node's view of the boundary-exchange fabric. Implementations carry
/// the node's identity; `to` is a logical rank on the current (compacted)
/// cluster. `recv_for` must deliver **exactly** `expect` patches tagged
/// `boundary` into `store`, buffering any patches that arrive early for
/// later boundaries (a fast peer may run ahead) — or return a typed error
/// when a peer's death or a deadline makes that impossible. Death must
/// surface *mid-wait*, not only at batch boundaries: both backends watch
/// liveness while blocked.
pub trait Exchange {
    fn send(
        &mut self,
        to: usize,
        boundary: usize,
        patch: RegionTensor,
    ) -> Result<(), TransportError>;

    fn recv_for(
        &mut self,
        boundary: usize,
        expect: usize,
        store: &mut PatchStore,
    ) -> Result<(), TransportError>;
}
