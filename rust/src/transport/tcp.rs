//! Real-socket fabric: TCP (and Unix domain sockets where available)
//! carrying [`super::codec`] frames between node processes.
//!
//! Address strings are `"tcp:host:port"` (or bare `host:port`) and
//! `"unix:/path/to.sock"`. Binding port 0 auto-allocates; [`listen`]
//! returns the canonical bound address for registration.
//!
//! [`TcpExchange`] implements [`super::Exchange`] — the same lockstep
//! protocol [`crate::cluster`] runs over channels — on a full peer mesh:
//!
//! * **Connect**: lower logical rank dials higher rank's data address
//!   (with retry + exponential backoff up to a deadline); higher rank
//!   accepts and identifies the peer from its `Hello` frame. Connections
//!   carrying a stale term are dropped at the door.
//! * **Receive**: one blocking reader thread per peer decodes frames into
//!   a shared event queue; `recv_for` drains it with the same
//!   ahead-boundary buffering the simulated Mailbox uses (plus a seq tag,
//!   since a process serves many inferences over one mesh).
//! * **Liveness**: a beacon thread sends `Heartbeat` every
//!   `heartbeat_interval`; readers stamp `last_seen` per peer. While
//!   blocked, `recv_for` wakes every [`TCP_TICK`] and surfaces a broken
//!   connection (SIGKILL → EOF/reset) or silent peer (missed heartbeats)
//!   as [`TransportError::PeerDead`] — *mid-batch*, which is what lets
//!   the serving layer fail a request explicitly instead of hanging.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compute::{PatchStore, RegionTensor};
use crate::transport::codec::{self, Frame, WireMsg};
use crate::transport::{Exchange, TransportError};

/// How often a blocked `recv_for` wakes to check liveness and deadlines.
const TCP_TICK: Duration = Duration::from_millis(10);

/// A bound listening socket on either fabric.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

/// A connected stream on either fabric.
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

impl Stream {
    pub fn try_clone(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    pub fn shutdown_both(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

impl Listener {
    pub fn set_nonblocking(&self, v: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(v),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(v),
        }
    }

    fn accept_stream(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                Stream::Tcp(s)
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Stream::Unix(s)
            }
        })
    }

    /// Blocking accept (restores blocking mode first).
    pub fn accept_blocking(&self) -> std::io::Result<Stream> {
        self.set_nonblocking(false)?;
        let s = self.accept_stream()?;
        prepare_stream(&s)?;
        Ok(s)
    }

    /// Accept on a listener already in non-blocking mode; `WouldBlock`
    /// surfaces as the error it is.
    pub fn accept_nonblocking(&self) -> std::io::Result<Stream> {
        let s = self.accept_stream()?;
        prepare_stream(&s)?;
        Ok(s)
    }
}

fn prepare_stream(s: &Stream) -> std::io::Result<()> {
    // accepted sockets can inherit the listener's non-blocking flag on some
    // platforms; force a known state and disable Nagle on TCP (frames are
    // latency-sensitive and already batched)
    match s {
        Stream::Tcp(t) => {
            t.set_nonblocking(false)?;
            t.set_nodelay(true)?;
        }
        #[cfg(unix)]
        Stream::Unix(u) => u.set_nonblocking(false)?,
    }
    Ok(())
}

/// Bind `addr` (`tcp:host:port`, bare `host:port`, or `unix:/path`) and
/// return the listener plus its canonical address (resolving port 0).
pub fn listen(addr: &str) -> std::io::Result<(Listener, String)> {
    if let Some(path) = addr.strip_prefix("unix:") {
        #[cfg(unix)]
        {
            let l = UnixListener::bind(path)?;
            return Ok((Listener::Unix(l), format!("unix:{path}")));
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            return Err(std::io::Error::other("unix sockets unsupported on this platform"));
        }
    }
    let hostport = addr.strip_prefix("tcp:").unwrap_or(addr);
    let l = TcpListener::bind(hostport)?;
    let canonical = format!("tcp:{}", l.local_addr()?);
    Ok((Listener::Tcp(l), canonical))
}

/// Dial `addr` once.
pub fn connect(addr: &str) -> std::io::Result<Stream> {
    if let Some(path) = addr.strip_prefix("unix:") {
        #[cfg(unix)]
        {
            let s = UnixStream::connect(path)?;
            let s = Stream::Unix(s);
            prepare_stream(&s)?;
            return Ok(s);
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            return Err(std::io::Error::other("unix sockets unsupported on this platform"));
        }
    }
    let hostport = addr.strip_prefix("tcp:").unwrap_or(addr);
    let s = Stream::Tcp(TcpStream::connect(hostport)?);
    prepare_stream(&s)?;
    Ok(s)
}

/// Dial `addr` with exponential backoff (10ms doubling, 200ms cap) until
/// it answers or `deadline` elapses — peers come up in arbitrary order.
pub fn connect_retry(addr: &str, deadline: Duration) -> Result<Stream, TransportError> {
    let start = Instant::now();
    let mut backoff = Duration::from_millis(10);
    loop {
        match connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if start.elapsed() >= deadline {
                    return Err(TransportError::Io(format!(
                        "connect to {addr} timed out after {:?}: {e}",
                        start.elapsed()
                    )));
                }
                std::thread::sleep(backoff.min(deadline.saturating_sub(start.elapsed())));
                backoff = (backoff * 2).min(Duration::from_millis(200));
            }
        }
    }
}

/// Write one frame (length-prefixed by its header) and flush.
pub fn send_frame(stream: &mut Stream, frame: &Frame) -> std::io::Result<()> {
    let bytes = codec::encode(frame);
    stream.write_all(&bytes)?;
    stream.flush()
}

/// Read one frame, blocking (or honoring the stream's read timeout, which
/// surfaces as an `Io` error). EOF and decode failures are typed.
pub fn read_frame(stream: &mut Stream) -> Result<Frame, TransportError> {
    let mut head = [0u8; codec::HEADER_LEN];
    stream.read_exact(&mut head)?;
    let h = codec::decode_header(&head)?;
    let mut payload = vec![0u8; h.payload_len as usize];
    stream.read_exact(&mut payload)?;
    Ok(codec::decode_body(&h, &payload)?)
}

/// One request/one reply over a fresh connection — the registry RPC shape.
pub fn roundtrip(addr: &str, frame: &Frame, deadline: Duration) -> Result<Frame, TransportError> {
    let mut s = connect_retry(addr, deadline)?;
    send_frame(&mut s, frame)?;
    s.set_read_timeout(Some(deadline))?;
    read_frame(&mut s)
}

/// Timing knobs for the socket fabric.
#[derive(Debug, Clone, Copy)]
pub struct TcpOpts {
    /// How long mesh bring-up may take (dials + accepts).
    pub connect_deadline: Duration,
    /// Bound on any single `recv_for` wait.
    pub recv_deadline: Duration,
    /// Beacon period.
    pub heartbeat_interval: Duration,
    /// Silence longer than this marks a peer dead.
    pub heartbeat_timeout: Duration,
}

impl Default for TcpOpts {
    fn default() -> TcpOpts {
        TcpOpts {
            connect_deadline: Duration::from_secs(10),
            recv_deadline: Duration::from_secs(30),
            heartbeat_interval: Duration::from_millis(100),
            heartbeat_timeout: Duration::from_millis(1200),
        }
    }
}

enum Event {
    Patch { seq: u64, boundary: usize, patch: RegionTensor },
    Dead { from: usize },
}

/// The real-socket [`Exchange`]: a mesh of framed connections between this
/// node process and every peer in the current plan generation.
pub struct TcpExchange {
    rank: usize,
    my_id: u32,
    term: u64,
    /// Seq of the inference currently executing — stamps outgoing patches,
    /// filters stale incoming ones.
    cur_seq: u64,
    writers: Vec<Option<Arc<Mutex<Stream>>>>,
    events: Receiver<Event>,
    pending: Vec<(u64, usize, RegionTensor)>,
    last_seen: Arc<Vec<AtomicU64>>,
    epoch: Instant,
    opts: TcpOpts,
    stop: Arc<AtomicBool>,
}

impl TcpExchange {
    /// Bring up the data-plane mesh for one plan generation. `peers` lists
    /// `(node id, data addr)` by logical rank (`peers[rank]` is this node);
    /// `listener` is this node's bound data listener, reused across
    /// generations. Lower ranks dial higher ranks; term-mismatched or
    /// unidentifiable connections are rejected.
    pub fn connect(
        rank: usize,
        peers: &[(u32, String)],
        listener: &Listener,
        term: u64,
        opts: TcpOpts,
    ) -> Result<TcpExchange, TransportError> {
        let nodes = peers.len();
        let my_id = peers[rank].0;
        let start = Instant::now();
        let mut streams: Vec<Option<Stream>> = (0..nodes).map(|_| None).collect();

        // dial every higher rank
        for (j, (_, addr)) in peers.iter().enumerate().skip(rank + 1) {
            let remaining = opts.connect_deadline.saturating_sub(start.elapsed());
            let mut s = connect_retry(addr, remaining)?;
            send_frame(&mut s, &Frame { node: my_id, term, msg: WireMsg::Hello })?;
            streams[j] = Some(s);
        }

        // accept every lower rank, identifying each from its Hello
        if rank > 0 {
            listener.set_nonblocking(true)?;
            let mut need = rank;
            while need > 0 {
                if start.elapsed() >= opts.connect_deadline {
                    return Err(TransportError::Io(format!(
                        "mesh accept timed out with {need} peers missing"
                    )));
                }
                match listener.accept_stream() {
                    Ok(s) => {
                        prepare_stream(&s)?;
                        s.set_read_timeout(Some(
                            opts.connect_deadline.saturating_sub(start.elapsed()),
                        ))?;
                        let mut s = s;
                        let hello = match read_frame(&mut s) {
                            Ok(f) => f,
                            Err(_) => continue, // broken dialer; keep waiting
                        };
                        if hello.term != term || !matches!(hello.msg, WireMsg::Hello) {
                            s.shutdown_both(); // stale generation or confusion
                            continue;
                        }
                        let Some(j) = peers.iter().position(|(id, _)| *id == hello.node) else {
                            s.shutdown_both();
                            continue;
                        };
                        if j >= rank || streams[j].is_some() {
                            s.shutdown_both();
                            continue;
                        }
                        s.set_read_timeout(None)?;
                        streams[j] = Some(s);
                        need -= 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            listener.set_nonblocking(false)?;
        }

        // spawn one reader per peer + the heartbeat beacon
        let (tx, rx) = channel::<Event>();
        let epoch = Instant::now();
        let last_seen: Arc<Vec<AtomicU64>> =
            Arc::new((0..nodes).map(|_| AtomicU64::new(0)).collect());
        let stop = Arc::new(AtomicBool::new(false));
        let mut writers: Vec<Option<Arc<Mutex<Stream>>>> = Vec::with_capacity(nodes);
        for (j, slot) in streams.into_iter().enumerate() {
            let Some(s) = slot else {
                writers.push(None);
                continue;
            };
            let reader = s.try_clone()?;
            writers.push(Some(Arc::new(Mutex::new(s))));
            spawn_reader(reader, j, term, tx.clone(), Arc::clone(&last_seen), epoch);
        }
        spawn_beacon(my_id, term, &writers, Arc::clone(&stop), opts.heartbeat_interval);

        Ok(TcpExchange {
            rank,
            my_id,
            term,
            cur_seq: 0,
            writers,
            events: rx,
            pending: Vec::new(),
            last_seen,
            epoch,
            opts,
            stop,
        })
    }

    /// Stamp subsequent sends/receives with inference `seq`; drops any
    /// buffered patches from earlier inferences.
    pub fn set_seq(&mut self, seq: u64) {
        self.cur_seq = seq;
        self.pending.retain(|(s, _, _)| *s >= seq);
    }

    pub fn term(&self) -> u64 {
        self.term
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// A peer whose heartbeats have gone silent, if any.
    fn stale_peer(&self) -> Option<usize> {
        let now = self.now_ms();
        let cutoff = self.opts.heartbeat_timeout.as_millis() as u64;
        (0..self.writers.len()).find(|&j| {
            j != self.rank
                && self.writers[j].is_some()
                && now.saturating_sub(self.last_seen[j].load(Ordering::SeqCst)) > cutoff
        })
    }
}

fn spawn_reader(
    mut stream: Stream,
    from: usize,
    term: u64,
    tx: Sender<Event>,
    last_seen: Arc<Vec<AtomicU64>>,
    epoch: Instant,
) {
    std::thread::spawn(move || loop {
        match read_frame(&mut stream) {
            Ok(f) => {
                if f.term != term {
                    continue; // stale generation talking; ignore
                }
                last_seen[from].store(epoch.elapsed().as_millis() as u64, Ordering::SeqCst);
                if let WireMsg::Patch { seq, boundary, patch } = f.msg {
                    if tx
                        .send(Event::Patch { seq, boundary: boundary as usize, patch })
                        .is_err()
                    {
                        break; // exchange dropped
                    }
                }
                // Heartbeat/Hello only refresh last_seen
            }
            Err(_) => {
                // EOF, reset, or garbage: either way this peer is unusable
                let _ = tx.send(Event::Dead { from });
                break;
            }
        }
    });
}

fn spawn_beacon(
    my_id: u32,
    term: u64,
    writers: &[Option<Arc<Mutex<Stream>>>],
    stop: Arc<AtomicBool>,
    interval: Duration,
) {
    let targets: Vec<Arc<Mutex<Stream>>> = writers.iter().flatten().map(Arc::clone).collect();
    if targets.is_empty() {
        return;
    }
    std::thread::spawn(move || {
        let beat = Frame { node: my_id, term, msg: WireMsg::Heartbeat };
        while !stop.load(Ordering::SeqCst) {
            for w in &targets {
                let mut s = w.lock().unwrap();
                let _ = send_frame(&mut s, &beat); // reader side notices death
            }
            std::thread::sleep(interval);
        }
    });
}

impl Drop for TcpExchange {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in self.writers.iter().flatten() {
            w.lock().unwrap().shutdown_both(); // unblocks our readers and peers'
        }
    }
}

impl Exchange for TcpExchange {
    fn send(
        &mut self,
        to: usize,
        boundary: usize,
        patch: RegionTensor,
    ) -> Result<(), TransportError> {
        let w = self.writers[to].as_ref().ok_or(TransportError::PeerDead(to))?;
        let frame = Frame {
            node: self.my_id,
            term: self.term,
            msg: WireMsg::Patch { seq: self.cur_seq, boundary: boundary as u32, patch },
        };
        let mut s = w.lock().unwrap();
        send_frame(&mut s, &frame).map_err(|_| TransportError::PeerDead(to))
    }

    fn recv_for(
        &mut self,
        boundary: usize,
        expect: usize,
        store: &mut PatchStore,
    ) -> Result<(), TransportError> {
        let mut got = 0usize;
        let mut i = 0;
        while i < self.pending.len() {
            let (s, b, _) = &self.pending[i];
            if *s == self.cur_seq && *b == boundary {
                let (_, _, patch) = self.pending.swap_remove(i);
                store.add(patch);
                got += 1;
            } else {
                i += 1;
            }
        }
        let start = Instant::now();
        while got < expect {
            match self.events.recv_timeout(TCP_TICK) {
                Ok(Event::Patch { seq, boundary: b, patch }) => {
                    if seq < self.cur_seq {
                        continue; // remnant of an inference that already failed
                    }
                    if seq == self.cur_seq && b == boundary {
                        store.add(patch);
                        got += 1;
                    } else if (seq, b) > (self.cur_seq, boundary) {
                        self.pending.push((seq, b, patch));
                    } else {
                        return Err(TransportError::Protocol(format!(
                            "stale patch for boundary {b} while at {boundary}"
                        )));
                    }
                }
                Ok(Event::Dead { from }) => return Err(TransportError::PeerDead(from)),
                Err(RecvTimeoutError::Timeout) => {
                    if let Some(p) = self.stale_peer() {
                        return Err(TransportError::PeerDead(p));
                    }
                    if start.elapsed() > self.opts.recv_deadline {
                        return Err(TransportError::Deadline { boundary, got, expect });
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::Protocol("event channel closed".into()));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::Tensor;
    use crate::partition::Region;

    fn mesh2(opts: TcpOpts) -> (TcpExchange, TcpExchange) {
        // two nodes on localhost: rank 0 dials rank 1
        let (l0, _a0) = listen("tcp:127.0.0.1:0").unwrap();
        let (l1, a1) = listen("tcp:127.0.0.1:0").unwrap();
        let peers = vec![(10u32, "tcp:unused".to_string()), (11u32, a1)];
        let peers2 = peers.clone();
        let h = std::thread::spawn(move || TcpExchange::connect(1, &peers2, &l1, 7, opts).unwrap());
        let ex0 = TcpExchange::connect(0, &peers, &l0, 7, opts).unwrap();
        let ex1 = h.join().unwrap();
        (ex0, ex1)
    }

    fn patch(v: f32) -> RegionTensor {
        let r = Region::new(0, 1, 0, 2, 0, 1);
        let mut t = Tensor::zeros(1, 2, 1);
        t.data[0] = v;
        t.data[1] = -v;
        RegionTensor::new(r, t)
    }

    #[test]
    fn patches_cross_the_wire_bit_exactly() {
        let (mut ex0, mut ex1) = mesh2(TcpOpts::default());
        ex0.set_seq(0);
        ex1.set_seq(0);
        ex0.send(1, 3, patch(1.25)).unwrap();
        let mut store = PatchStore::new();
        ex1.recv_for(3, 1, &mut store).unwrap();
        assert_eq!(store.patches.len(), 1);
        assert_eq!(store.patches[0].t.data, vec![1.25, -1.25]);
    }

    #[test]
    fn ahead_boundary_patches_buffer_until_their_turn() {
        let (mut ex0, mut ex1) = mesh2(TcpOpts::default());
        ex0.set_seq(0);
        ex1.set_seq(0);
        // a fast peer already sends boundary 2 while we still wait on 1
        ex0.send(1, 2, patch(2.0)).unwrap();
        ex0.send(1, 1, patch(1.0)).unwrap();
        let mut s1 = PatchStore::new();
        ex1.recv_for(1, 1, &mut s1).unwrap();
        assert_eq!(s1.patches[0].t.data[0], 1.0);
        let mut s2 = PatchStore::new();
        ex1.recv_for(2, 1, &mut s2).unwrap();
        assert_eq!(s2.patches[0].t.data[0], 2.0);
    }

    #[test]
    fn tcp_exchange_surfaces_connection_death_mid_wait() {
        // peer's sockets close (what SIGKILL does to them) while we block
        // in recv_for: the reader's EOF must surface as PeerDead mid-wait,
        // long before the 30s recv deadline
        let (ex0, mut ex1) = mesh2(TcpOpts::default());
        let killer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            drop(ex0); // shuts the connection down hard
        });
        let start = Instant::now();
        let mut store = PatchStore::new();
        let err = ex1.recv_for(0, 1, &mut store).unwrap_err();
        assert_eq!(err, TransportError::PeerDead(0));
        assert!(start.elapsed() < Duration::from_secs(5));
        killer.join().unwrap();
    }

    #[test]
    fn tcp_exchange_surfaces_silent_peer_via_missed_heartbeats() {
        // the peer's connection stays open but it stops beating (a wedged
        // process, a pulled cable): staleness must surface as PeerDead
        let mut opts = TcpOpts::default();
        opts.heartbeat_interval = Duration::from_secs(3600); // never beats
        opts.heartbeat_timeout = Duration::from_millis(150);
        let (_ex0, mut ex1) = mesh2(opts);
        let start = Instant::now();
        let mut store = PatchStore::new();
        let err = ex1.recv_for(0, 1, &mut store).unwrap_err();
        assert_eq!(err, TransportError::PeerDead(0));
        assert!(start.elapsed() < Duration::from_secs(5), "not detected mid-wait");
    }

    #[test]
    fn stale_seq_patches_are_dropped_not_delivered() {
        let (mut ex0, mut ex1) = mesh2(TcpOpts::default());
        ex0.set_seq(3);
        ex0.send(1, 0, patch(3.0)).unwrap();
        ex0.set_seq(4);
        ex0.send(1, 0, patch(4.0)).unwrap();
        // receiver is already on seq 4: the seq-3 patch must not count
        ex1.set_seq(4);
        let mut store = PatchStore::new();
        ex1.recv_for(0, 1, &mut store).unwrap();
        assert_eq!(store.patches.len(), 1);
        assert_eq!(store.patches[0].t.data[0], 4.0);
    }

    #[test]
    fn socket_reads_reject_malformed_frames_with_typed_errors() {
        // satellite: malformed bytes through a real socket (not just the
        // codec unit tests) — each flavor surfaces as its typed error
        let (l, a) = listen("tcp:127.0.0.1:0").unwrap();
        let server = std::thread::spawn(move || {
            (0..3)
                .map(|_| {
                    let mut s = l.accept_blocking().unwrap();
                    read_frame(&mut s).unwrap_err()
                })
                .collect::<Vec<_>>()
        });
        let good = codec::encode(&Frame {
            node: 1,
            term: 0,
            msg: WireMsg::Patch { seq: 0, boundary: 0, patch: patch(1.0) },
        });
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let mut bad_sum = good.clone();
        let last = bad_sum.len() - 1;
        bad_sum[last] ^= 0x01; // one payload bit: FNV-1a must catch it
        let torn = good[..good.len() / 2].to_vec(); // half a frame, then EOF
        for bytes in [bad_magic, bad_sum, torn] {
            let mut s = connect(&a).unwrap();
            s.write_all(&bytes).unwrap();
            s.flush().unwrap();
            s.shutdown_both();
        }
        let errs = server.join().unwrap();
        assert!(
            matches!(errs[0], TransportError::Codec(codec::CodecError::BadMagic(_))),
            "clobbered magic: {:?}",
            errs[0]
        );
        assert!(
            matches!(errs[1], TransportError::Codec(codec::CodecError::BadChecksum { .. })),
            "flipped payload byte: {:?}",
            errs[1]
        );
        assert!(
            matches!(errs[2], TransportError::Io(_)),
            "torn frame hits EOF mid-payload: {:?}",
            errs[2]
        );
    }

    #[test]
    fn corrupt_frame_on_the_wire_never_reaches_numerics() {
        // acceptance invariant, tcp side: a checksum-corrupted patch is
        // rejected by the reader and the connection torn down — the
        // mangled tensor is never delivered, so corruption can only ever
        // surface as a typed failure, never as wrong numerics
        let (l1, a1) = listen("tcp:127.0.0.1:0").unwrap();
        let peers = vec![(10u32, "tcp:unused".to_string()), (11u32, a1)];
        let peers2 = peers.clone();
        let h = std::thread::spawn(move || {
            TcpExchange::connect(1, &peers2, &l1, 7, TcpOpts::default()).unwrap()
        });
        // hand-rolled rank 0: a real socket we can script raw bytes onto
        let mut s = connect_retry(&peers[1].1, Duration::from_secs(5)).unwrap();
        send_frame(&mut s, &Frame { node: 10, term: 7, msg: WireMsg::Hello }).unwrap();
        let mut ex1 = h.join().unwrap();
        ex1.set_seq(0);

        // a clean patch crosses bit-exactly…
        let clean = Frame {
            node: 10,
            term: 7,
            msg: WireMsg::Patch { seq: 0, boundary: 0, patch: patch(2.5) },
        };
        send_frame(&mut s, &clean).unwrap();
        let mut store = PatchStore::new();
        ex1.recv_for(0, 1, &mut store).unwrap();
        assert_eq!(store.patches[0].t.data, vec![2.5, -2.5]);

        // …then the same wire carries a corrupted copy: one flipped byte
        let mut bytes = codec::encode(&Frame {
            node: 10,
            term: 7,
            msg: WireMsg::Patch { seq: 0, boundary: 1, patch: patch(9.0) },
        });
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        s.write_all(&bytes).unwrap();
        s.flush().unwrap();
        let mut store = PatchStore::new();
        let err = ex1.recv_for(1, 1, &mut store).unwrap_err();
        assert_eq!(err, TransportError::PeerDead(0), "corruption tears the connection down");
        assert!(store.patches.is_empty(), "the mangled patch must never be delivered");
    }

    #[test]
    fn phantom_dup_boundary_patches_park_and_purge() {
        // the fault injector tags duplicate deliveries with boundary
        // u32::MAX: they must park in the reorder buffer without
        // displacing a real patch, and the next set_seq must purge them
        let (mut ex0, mut ex1) = mesh2(TcpOpts::default());
        ex0.set_seq(0);
        ex1.set_seq(0);
        ex0.send(1, u32::MAX as usize, patch(9.9)).unwrap();
        ex0.send(1, 0, patch(1.5)).unwrap();
        let mut store = PatchStore::new();
        ex1.recv_for(0, 1, &mut store).unwrap();
        assert_eq!(store.patches.len(), 1);
        assert_eq!(store.patches[0].t.data[0], 1.5);
        ex0.set_seq(1);
        ex1.set_seq(1);
        ex0.send(1, 0, patch(2.5)).unwrap();
        let mut store = PatchStore::new();
        ex1.recv_for(0, 1, &mut store).unwrap();
        assert_eq!(store.patches.len(), 1);
        assert_eq!(store.patches[0].t.data[0], 2.5);
    }

    #[cfg(unix)]
    #[test]
    fn unix_domain_socket_round_trip() {
        let dir = crate::util::tmp::TempDir::new("uds");
        let path = dir.path().join("node.sock");
        let addr = format!("unix:{}", path.display());
        let (l, canon) = listen(&addr).unwrap();
        assert_eq!(canon, addr);
        let h = std::thread::spawn(move || {
            let mut s = l.accept_blocking().unwrap();
            read_frame(&mut s).unwrap()
        });
        let mut s = connect(&addr).unwrap();
        send_frame(&mut s, &Frame { node: 5, term: 2, msg: WireMsg::Begin { seq: 77, trace: 0 } })
            .unwrap();
        let f = h.join().unwrap();
        assert_eq!((f.node, f.term), (5, 2));
        assert!(matches!(f.msg, WireMsg::Begin { seq: 77, .. }));
    }
}
