//! Registry: TTL-leased service discovery for node daemons.
//!
//! Daemons [`register`] `(node id, ctl addr, data addr, speed)` on boot and
//! [`renew`] the lease every `ttl/3`; anyone (the coordinator, mostly)
//! [`resolve`]s the **live** peer set — rows whose lease is unexpired. A
//! `kill -9`'d daemon stops renewing, its row ages out, and the next
//! resolve simply doesn't contain it: expiry is the real-world liveness
//! signal that feeds the election/failover path, replacing the simulated
//! world's scripted alive-masks.
//!
//! The wire shape is one request frame, one reply frame, one short-lived
//! connection per RPC (the codec's registry messages) — deliberately
//! boring, so a registry can also be a separate process
//! (`flexpie-ctl registry`) with nothing shared but the address.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::transport::codec::{Frame, RegistryEntry, WireMsg, CTL_NODE};
use crate::transport::{tcp, RetryPolicy, TransportError};

/// Deadline for one registry RPC round trip.
const RPC_DEADLINE: Duration = Duration::from_secs(5);

/// The default policy for registry RPCs: a few attempts with jittered
/// backoff, each bounded by the classic 5 s round-trip deadline. The
/// convenience wrappers ([`register`]/[`renew`]/[`resolve`]) use this; the
/// daemon threads its own [`RetryPolicy`] through the `_with` variants.
pub fn rpc_policy() -> RetryPolicy {
    RetryPolicy { deadline: RPC_DEADLINE, ..RetryPolicy::default() }
}

struct Row {
    ctl_addr: String,
    data_addr: String,
    speed: f64,
    renewed: Instant,
}

/// An in-process registry service listening on TCP (or UDS). Spawn one in
/// a test or example, or let `flexpie-ctl registry` host one in its own
/// process — clients cannot tell the difference.
pub struct RegistryServer {
    addr: String,
    stop: Arc<AtomicBool>,
}

impl RegistryServer {
    /// Bind `bind` (e.g. `"tcp:127.0.0.1:0"`) and serve until dropped.
    /// Leases last `ttl`.
    pub fn spawn(bind: &str, ttl: Duration) -> std::io::Result<RegistryServer> {
        let (listener, addr) = tcp::listen(bind)?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        std::thread::spawn(move || serve(listener, ttl, &stop2));
        Ok(RegistryServer { addr, stop })
    }

    /// The canonical bound address clients should dial.
    pub fn addr(&self) -> &str {
        &self.addr
    }
}

impl Drop for RegistryServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// The accept/dispatch loop — also the body of `flexpie-ctl registry`.
pub fn serve(listener: tcp::Listener, ttl: Duration, stop: &AtomicBool) {
    let mut table: HashMap<u32, Row> = HashMap::new();
    let ttl_ms = ttl.as_millis() as u64;
    while !stop.load(Ordering::SeqCst) {
        let mut stream = match listener_poll(&listener) {
            Some(s) => s,
            None => continue,
        };
        // one request, one reply; a slow or hostile client can't wedge us
        if stream.set_read_timeout(Some(Duration::from_secs(1))).is_err() {
            continue;
        }
        let req = match tcp::read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => continue,
        };
        let reply = match req.msg {
            WireMsg::Register { ctl_addr, data_addr, speed } => {
                table.insert(
                    req.node,
                    Row { ctl_addr, data_addr, speed, renewed: Instant::now() },
                );
                WireMsg::RegisterOk { ttl_ms }
            }
            WireMsg::Renew => {
                if let Some(row) = table.get_mut(&req.node) {
                    row.renewed = Instant::now();
                }
                WireMsg::RenewOk
            }
            WireMsg::Resolve => {
                let mut entries: Vec<RegistryEntry> = table
                    .iter()
                    .filter(|(_, row)| row.renewed.elapsed() < ttl)
                    .map(|(&node, row)| RegistryEntry {
                        node,
                        ctl_addr: row.ctl_addr.clone(),
                        data_addr: row.data_addr.clone(),
                        speed: row.speed,
                    })
                    .collect();
                entries.sort_by_key(|e| e.node);
                WireMsg::ResolveOk { entries }
            }
            WireMsg::Shutdown => break,
            _ => continue, // not a registry RPC; drop the connection
        };
        let frame = Frame { node: CTL_NODE, term: 0, msg: reply };
        let _ = tcp::send_frame(&mut stream, &frame);
    }
}

fn listener_poll(listener: &tcp::Listener) -> Option<tcp::Stream> {
    match listener.accept_nonblocking() {
        Ok(s) => Some(s),
        Err(_) => {
            std::thread::sleep(Duration::from_millis(5));
            None
        }
    }
}

/// Announce a daemon; returns the lease TTL in ms the server granted.
pub fn register(
    registry: &str,
    node: u32,
    ctl_addr: &str,
    data_addr: &str,
    speed: f64,
) -> Result<u64, TransportError> {
    register_with(&rpc_policy(), registry, node, ctl_addr, data_addr, speed)
}

/// [`register`] under an explicit [`RetryPolicy`] — each attempt is one
/// fresh connect + round trip, so a registry that comes up a beat after
/// its daemons is absorbed instead of fatal.
pub fn register_with(
    policy: &RetryPolicy,
    registry: &str,
    node: u32,
    ctl_addr: &str,
    data_addr: &str,
    speed: f64,
) -> Result<u64, TransportError> {
    let req = Frame {
        node,
        term: 0,
        msg: WireMsg::Register {
            ctl_addr: ctl_addr.to_string(),
            data_addr: data_addr.to_string(),
            speed,
        },
    };
    policy.run("registry.register", |_| {
        match tcp::roundtrip(registry, &req, policy.deadline)?.msg {
            WireMsg::RegisterOk { ttl_ms } => Ok(ttl_ms),
            other => Err(TransportError::Protocol(format!(
                "registry answered Register with type {}",
                other.kind()
            ))),
        }
    })
}

/// Renew a daemon's lease.
pub fn renew(registry: &str, node: u32) -> Result<(), TransportError> {
    renew_with(&rpc_policy(), registry, node)
}

/// [`renew`] under an explicit [`RetryPolicy`]. A renewal that misses all
/// its attempts is reported — the caller decides whether the lease is
/// worth keeping alive (the daemon gives up only when the registry stays
/// gone).
pub fn renew_with(policy: &RetryPolicy, registry: &str, node: u32) -> Result<(), TransportError> {
    let req = Frame { node, term: 0, msg: WireMsg::Renew };
    policy.run("registry.renew", |_| {
        match tcp::roundtrip(registry, &req, policy.deadline)?.msg {
            WireMsg::RenewOk => Ok(()),
            other => Err(TransportError::Protocol(format!(
                "registry answered Renew with type {}",
                other.kind()
            ))),
        }
    })
}

/// The live (lease-unexpired) peer set, sorted by node id.
pub fn resolve(registry: &str) -> Result<Vec<RegistryEntry>, TransportError> {
    resolve_with(&rpc_policy(), registry)
}

/// [`resolve`] under an explicit [`RetryPolicy`].
pub fn resolve_with(
    policy: &RetryPolicy,
    registry: &str,
) -> Result<Vec<RegistryEntry>, TransportError> {
    let req = Frame { node: CTL_NODE, term: 0, msg: WireMsg::Resolve };
    policy.run("registry.resolve", |_| {
        match tcp::roundtrip(registry, &req, policy.deadline)?.msg {
            WireMsg::ResolveOk { entries } => Ok(entries),
            other => Err(TransportError::Protocol(format!(
                "registry answered Resolve with type {}",
                other.kind()
            ))),
        }
    })
}

/// Poll [`resolve`] until at least `min` daemons are live or `deadline`
/// passes — cluster bring-up barrier.
pub fn await_nodes(
    registry: &str,
    min: usize,
    deadline: Duration,
) -> Result<Vec<RegistryEntry>, TransportError> {
    let start = Instant::now();
    loop {
        let entries = resolve(registry)?;
        if entries.len() >= min {
            return Ok(entries);
        }
        if start.elapsed() >= deadline {
            return Err(TransportError::Io(format!(
                "only {}/{min} daemons registered within {deadline:?}",
                entries.len()
            )));
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Ask a registry process to exit (used by process supervisors in tests).
pub fn shutdown(registry: &str) -> Result<(), TransportError> {
    let req = Frame { node: CTL_NODE, term: 0, msg: WireMsg::Shutdown };
    let mut s = tcp::connect_retry(registry, RPC_DEADLINE)?;
    tcp::send_frame(&mut s, &req)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_resolve_round_trip() {
        let srv = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_secs(5)).unwrap();
        let ttl = register(srv.addr(), 2, "tcp:1.2.3.4:10", "tcp:1.2.3.4:11", 1.0).unwrap();
        assert_eq!(ttl, 5000);
        register(srv.addr(), 0, "tcp:1.2.3.4:20", "tcp:1.2.3.4:21", 2.0).unwrap();
        let entries = resolve(srv.addr()).unwrap();
        assert_eq!(entries.len(), 2);
        // sorted by node id
        assert_eq!(entries[0].node, 0);
        assert_eq!(entries[1].node, 2);
        assert_eq!(entries[1].data_addr, "tcp:1.2.3.4:11");
        assert_eq!(entries[0].speed, 2.0);
    }

    #[test]
    fn leases_expire_without_renewal() {
        let srv = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_millis(80)).unwrap();
        register(srv.addr(), 7, "tcp:a:1", "tcp:a:2", 1.0).unwrap();
        assert_eq!(resolve(srv.addr()).unwrap().len(), 1);
        std::thread::sleep(Duration::from_millis(160));
        assert!(
            resolve(srv.addr()).unwrap().is_empty(),
            "a dead daemon's lease must age out — this is the liveness signal"
        );
    }

    #[test]
    fn renewal_keeps_the_lease_alive() {
        let srv = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_millis(120)).unwrap();
        register(srv.addr(), 3, "tcp:a:1", "tcp:a:2", 1.0).unwrap();
        for _ in 0..5 {
            std::thread::sleep(Duration::from_millis(60));
            renew(srv.addr(), 3).unwrap();
        }
        // 300ms elapsed — far past the ttl, alive only because of renewals
        let entries = resolve(srv.addr()).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].node, 3);
    }

    #[cfg(unix)]
    #[test]
    fn boot_registration_survives_a_late_registry() {
        // the PR 7 hardening case: a daemon boots before its registry is
        // listening. With per-attempt deadlines far shorter than the
        // registry's arrival, only the policy's retries can save the boot.
        let dir = crate::util::tmp::TempDir::new("latereg");
        let addr = format!("unix:{}", dir.path().join("registry.sock").display());
        let policy = RetryPolicy {
            attempts: 10,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(40),
            deadline: Duration::from_millis(100),
            ..RetryPolicy::default()
        };
        let registrar = {
            let addr = addr.clone();
            std::thread::spawn(move || register_with(&policy, &addr, 4, "tcp:a:1", "tcp:a:2", 1.0))
        };
        // the daemon is already dialing; the registry shows up a beat later
        std::thread::sleep(Duration::from_millis(250));
        let srv = RegistryServer::spawn(&addr, Duration::from_secs(5)).unwrap();
        let ttl = registrar.join().unwrap().expect("retries must absorb the late registry");
        assert_eq!(ttl, 5000);
        let entries = resolve(srv.addr()).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].node, 4);
    }

    #[test]
    fn await_nodes_barrier_fills_or_times_out() {
        let srv = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_secs(5)).unwrap();
        let addr = srv.addr().to_string();
        let joiner = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(60));
                register(&addr, 1, "tcp:a:1", "tcp:a:2", 1.0).unwrap();
            })
        };
        let entries = await_nodes(&addr, 1, Duration::from_secs(5)).unwrap();
        assert_eq!(entries.len(), 1);
        joiner.join().unwrap();
        assert!(await_nodes(&addr, 5, Duration::from_millis(100)).is_err());
    }
}
