//! Node daemon: one process (or thread, for benches/examples) per device.
//!
//! Boot sequence: bind a control listener (coordinator dials it) and a
//! data listener (peers dial it), register both with the TTL
//! [`super::registry`], start a lease-renewal thread, then serve control
//! frames forever:
//!
//! * `PlanInstall` — tear down the previous generation, derive weights
//!   from the wire seed ([`crate::compute::WeightStore::for_model`] is
//!   deterministic, so no weight bytes ever travel), compute the plan
//!   geometry exactly as the in-process nodes do, bring up the
//!   [`super::tcp::TcpExchange`] mesh for the install's term, ack `Ready`.
//! * `Begin`/`Infer` — run the **same** lockstep protocol
//!   ([`crate::cluster`]'s `node_main`) over the socket mesh; the leader
//!   (logical rank 0) gets the input via `Infer` and returns `Output`,
//!   workers join via `Begin`. A transport failure mid-inference surfaces
//!   as an explicit `Failed` frame from the leader (never a silent drop)
//!   and poisons the generation until the next install.
//! * `Shutdown` — exit cleanly.
//!
//! The daemon never loads a model from disk and never trusts wall-clock
//! agreement with its peers: everything it needs arrives in the install
//! frame, which is what makes `kill -9` + reinstall a complete recovery
//! story.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use std::time::Instant;

use crate::compute::{Tensor, WeightStore};
use crate::loadgen::procfs;
use crate::model::Model;
use crate::partition::inflate::BlockGeometry;
use crate::partition::Scheme;
use crate::trace::{FlightRecorder, SpanRecord, KIND_SERVICE};
use crate::transport::codec::{Frame, WireMsg, CTL_NODE};
use crate::transport::fault::{FaultExchange, FaultSchedule};
use crate::transport::tcp::{self, TcpExchange, TcpOpts};
use crate::transport::{registry, RetryPolicy, TransportError};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct DaemonOpts {
    /// Stable node identity (survives re-registration).
    pub node: u32,
    /// Registry address to register with and renew against.
    pub registry: String,
    /// Bind address for the control plane (default: ephemeral TCP).
    pub ctl_bind: String,
    /// Bind address for the data plane (TCP or `unix:`).
    pub data_bind: String,
    /// Advertised relative compute speed.
    pub speed: f64,
    /// Socket-fabric timing knobs.
    pub tcp: TcpOpts,
    /// Retry policy for registry RPCs (boot registration, lease renewal).
    pub retry: RetryPolicy,
    /// Wire-fault schedule to replay against this daemon's data plane
    /// (`None` = transparent). The send-op clock persists across plan
    /// generations, so a schedule keeps advancing through failovers.
    pub fault: Option<FaultSchedule>,
    /// Print a `READY node=… ctl=… data=…` line on boot — process
    /// supervisors (tests, `flexpie-ctl`) wait for it.
    pub announce: bool,
}

impl DaemonOpts {
    pub fn new(node: u32, registry: &str) -> DaemonOpts {
        DaemonOpts {
            node,
            registry: registry.to_string(),
            ctl_bind: "tcp:127.0.0.1:0".into(),
            data_bind: "tcp:127.0.0.1:0".into(),
            speed: 1.0,
            tcp: TcpOpts::default(),
            retry: registry::rpc_policy(),
            fault: None,
            announce: false,
        }
    }
}

/// One installed plan generation: everything needed to run inferences
/// until the coordinator replaces it.
struct Generation {
    term: u64,
    rank: usize,
    nodes: usize,
    peers: Vec<(u32, String)>,
    model: Model,
    weights: WeightStore,
    blocks: Vec<(usize, usize, Scheme)>,
    geos: Vec<BlockGeometry>,
    /// The socket mesh, behind the wire-fault injector (transparent when
    /// no schedule is configured).
    ex: FaultExchange<TcpExchange>,
}

/// Run the daemon until a `Shutdown` frame (or an unrecoverable listener
/// error). Blocks the calling thread; spawn it for in-thread clusters.
pub fn run(opts: DaemonOpts) -> Result<(), TransportError> {
    let (ctl_l, ctl_addr) = tcp::listen(&opts.ctl_bind)?;
    let (data_l, data_addr) = tcp::listen(&opts.data_bind)?;
    let ttl_ms = registry::register_with(
        &opts.retry,
        &opts.registry,
        opts.node,
        &ctl_addr,
        &data_addr,
        opts.speed,
    )?;

    // renew the lease at ttl/3 — stopping (or dying) lets it expire, which
    // is exactly how the rest of the system learns we're gone
    let stop = Arc::new(AtomicBool::new(false));
    {
        let stop = Arc::clone(&stop);
        let reg = opts.registry.clone();
        let node = opts.node;
        let retry = opts.retry;
        let period = Duration::from_millis((ttl_ms / 3).max(10));
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(period);
                if registry::renew_with(&retry, &reg, node).is_err() {
                    break; // registry stayed gone; nothing to renew against
                }
            }
        });
    }

    if opts.announce {
        use std::io::Write as _;
        println!("READY node={} ctl={ctl_addr} data={data_addr}", opts.node);
        let _ = std::io::stdout().flush();
    }

    let result = control_loop(&opts, &ctl_l, &data_l);
    stop.store(true, Ordering::SeqCst);
    result
}

fn control_loop(
    opts: &DaemonOpts,
    ctl_l: &tcp::Listener,
    data_l: &tcp::Listener,
) -> Result<(), TransportError> {
    let mut gen: Option<Generation> = None;
    // the wire-fault send-op clock: carried across plan generations so a
    // replayed inference resumes where the aborted one stopped injecting
    // (a one-shot fault fires once, a windowed fault expires) instead of
    // rewinding to the same fault forever
    let mut fault_base: u64 = 0;
    // per-process flight recorder: traced inferences record their compute
    // span here; TraceDump ships (and implicitly keeps) its contents.
    // Resource accounting is a delta against this boot-time sample.
    let recorder = FlightRecorder::new();
    let usage0 = procfs::self_usage();
    loop {
        // one coordinator at a time; when it disconnects, await the next
        let mut ctl = ctl_l.accept_blocking()?;
        loop {
            let frame = match tcp::read_frame(&mut ctl) {
                Ok(f) => f,
                Err(_) => break,
            };
            match frame.msg {
                WireMsg::PlanInstall { leader: _, seed, model, plan, peers } => {
                    // tear the old mesh down before rebuilding; bank its
                    // fault clock first
                    if let Some(g) = gen.take() {
                        fault_base = g.ex.ops();
                    }
                    let Some(rank) = peers.iter().position(|(id, _)| *id == opts.node) else {
                        continue; // not a member of this generation
                    };
                    let nodes = peers.len();
                    let weights = WeightStore::for_model(&model, seed);
                    let (blocks, geos) = crate::cluster::plan_geometry(&model, &plan, nodes);
                    match TcpExchange::connect(rank, &peers, data_l, frame.term, opts.tcp) {
                        Ok(ex) => {
                            let schedule = Arc::new(
                                opts.fault.clone().unwrap_or_else(|| FaultSchedule::none(nodes)),
                            );
                            let ex = FaultExchange::with_offset(ex, rank, schedule, fault_base);
                            gen = Some(Generation {
                                term: frame.term,
                                rank,
                                nodes,
                                peers,
                                model,
                                weights,
                                blocks,
                                geos,
                                ex,
                            });
                            let _ = tcp::send_frame(
                                &mut ctl,
                                &Frame { node: opts.node, term: frame.term, msg: WireMsg::Ready },
                            );
                        }
                        Err(_) => {
                            // a peer died during bring-up; stay idle — the
                            // coordinator's Ready deadline triggers reinstall
                        }
                    }
                }
                WireMsg::Begin { seq, trace } => {
                    let ok = match gen.as_mut() {
                        Some(g) if frame.term == g.term => {
                            run_inference(g, seq, trace, None, &mut ctl, opts.node, &recorder)
                        }
                        _ => true,
                    };
                    if let Some(g) = gen.as_ref() {
                        fault_base = g.ex.ops();
                    }
                    if !ok {
                        gen = None;
                    }
                }
                WireMsg::Infer { seq, input, trace } => {
                    let ok = match gen.as_mut() {
                        Some(g) if frame.term == g.term => {
                            run_inference(g, seq, trace, Some(input), &mut ctl, opts.node, &recorder)
                        }
                        _ => true,
                    };
                    if let Some(g) = gen.as_ref() {
                        fault_base = g.ex.ops();
                    }
                    if !ok {
                        gen = None;
                    }
                }
                WireMsg::TraceDump => {
                    // ship the flight recorder plus this process's resource
                    // delta — the coordinator's per-node accounting source
                    let (rss_bytes, cpu_ms) = match (usage0, procfs::self_usage()) {
                        (Some(a), Some(b)) => {
                            let d = b.since(&a);
                            (d.rss_bytes, d.cpu_ms)
                        }
                        _ => (0, 0),
                    };
                    let _ = tcp::send_frame(
                        &mut ctl,
                        &Frame {
                            node: opts.node,
                            term: frame.term,
                            msg: WireMsg::TraceData {
                                spans: recorder.snapshot(),
                                rss_bytes,
                                cpu_ms,
                            },
                        },
                    );
                }
                WireMsg::Abort | WireMsg::Drain | WireMsg::Elect { .. } => {
                    // lockstep daemons hold nothing between frames; election
                    // is implied by rank order in the next install
                }
                WireMsg::Shutdown => return Ok(()),
                _ => {} // not a control message; ignore
            }
        }
    }
}

/// Execute one inference over the generation's mesh. Returns false when
/// the generation is poisoned (a transport failure) and must be replaced.
#[allow(clippy::too_many_arguments)]
fn run_inference(
    g: &mut Generation,
    seq: u64,
    trace: u64,
    input: Option<Tensor>,
    ctl: &mut tcp::Stream,
    my_id: u32,
    recorder: &FlightRecorder,
) -> bool {
    g.ex.inner_mut().set_seq(seq);
    let start_ns = recorder.now_ns();
    let t0 = Instant::now();
    let res = crate::cluster::node_main(
        g.rank,
        g.nodes,
        &g.model,
        &g.blocks,
        &g.geos,
        &g.weights,
        input.as_ref(),
        &mut g.ex,
        &crate::compute::ComputeConfig::default(),
    );
    let service_ns = t0.elapsed().as_nanos() as u64;
    if trace != 0 {
        recorder.record(SpanRecord {
            trace_id: trace,
            gen: g.term,
            kind: KIND_SERVICE,
            node: my_id,
            start_ns,
            dur_ns: service_ns,
        });
    }
    match res {
        Ok(nr) => {
            if g.rank == 0 {
                let output = nr.output.expect("leader produced no output");
                let traffic: Vec<(u64, u64)> =
                    nr.traffic.iter().map(|t| (t.bytes, t.msgs)).collect();
                // bytes/msgs are the leader's own sends — enough for the
                // overhead bench; the audit compares outputs, not wire totals
                let _ = tcp::send_frame(
                    ctl,
                    &Frame {
                        node: my_id,
                        term: g.term,
                        msg: WireMsg::Output {
                            seq,
                            output,
                            bytes: nr.sent_bytes,
                            msgs: nr.sent_msgs as u64,
                            traffic,
                            trace,
                            service_ns,
                        },
                    },
                );
            }
            true
        }
        Err(e) => {
            if g.rank == 0 {
                // name the culprit when we know it; CTL_NODE = "unknown,
                // consult the registry"
                let dead = match e {
                    TransportError::PeerDead(r) => {
                        g.peers.get(r).map(|(id, _)| *id).unwrap_or(CTL_NODE)
                    }
                    _ => CTL_NODE,
                };
                let _ = tcp::send_frame(
                    ctl,
                    &Frame {
                        node: my_id,
                        term: g.term,
                        msg: WireMsg::Failed { seq, node: dead },
                    },
                );
            }
            false
        }
    }
}
