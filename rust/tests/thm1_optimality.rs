//! Theorem 1 (Optimality): "Assuming the Cost Estimator always reports the
//! proper time cost for any given partition scheme, then DPP can output the
//! optimal partition scheme for a given DNN model that yields the lowest
//! time cost."
//!
//! Validated by brute force: DPP's plan cost must equal the exhaustive
//! minimum over *every* legal plan (all block compositions × scheme
//! assignments), under the same cost oracle — for any oracle (we test both
//! the analytic model and a trained GBDT CE), any testbed, with and without
//! pruning.

use flexpie::cost::estimator::Estimators;
use flexpie::cost::gbdt::GbdtParams;
use flexpie::cost::tracegen::TraceConfig;
use flexpie::cost::CostSource;
use flexpie::model::{zoo, ConvType, LayerMeta, Model};
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::Scheme;
use flexpie::planner::exhaustive::{exhaustive_plan, plan_cost};
use flexpie::planner::{Dpp, DppConfig};

fn assert_thm1(model: &Model, cost: &CostSource) {
    let dpp = Dpp::new(model, cost).plan();
    let brute = exhaustive_plan(model, cost, &Scheme::ALL);
    let dpp_cost = plan_cost(model, &dpp, cost).total;
    let tol = 1e-9 * brute.est_cost.max(1e-12);
    assert!(
        (dpp_cost - brute.est_cost).abs() <= tol,
        "{}: DPP {} ({}) vs exhaustive {} ({})",
        model.name,
        dpp_cost,
        dpp.render(),
        brute.est_cost,
        brute.render()
    );
    // DPP's own estimate must also equal its re-costed plan.
    assert!((dpp.est_cost - dpp_cost).abs() <= tol);
}

#[test]
fn thm1_tiny_chains_across_testbeds() {
    for n_layers in [1usize, 2, 3, 4] {
        let model = zoo::tiny_chain(n_layers, 12, 8);
        for nodes in [2usize, 3, 4] {
            for topo in [Topology::Ring, Topology::Ps, Topology::Mesh] {
                for gbps in [5.0, 0.5] {
                    let tb = Testbed::new(nodes, topo, Bandwidth::gbps(gbps));
                    assert_thm1(&model, &CostSource::analytic(&tb));
                }
            }
        }
    }
}

#[test]
fn thm1_heterogeneous_layer_chain() {
    // A chain mixing conv types, strides and channel growth — the shapes
    // that make scheme choice non-trivial.
    let layers = vec![
        LayerMeta::conv("c0", ConvType::Standard, 16, 16, 3, 8, 3, 1, 1),
        LayerMeta::conv("dw", ConvType::Depthwise, 16, 16, 8, 8, 3, 2, 1),
        LayerMeta::conv("pw", ConvType::Pointwise, 8, 8, 8, 32, 1, 1, 0),
        LayerMeta::conv("c1", ConvType::Standard, 8, 8, 32, 32, 3, 1, 1),
        LayerMeta::pool("gap", 8, 8, 32, 8, 8),
        LayerMeta::dense("fc", 1, 32, 10),
    ];
    let model = Model::new("hetero6", layers);
    for gbps in [5.0, 1.0, 0.2] {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(gbps));
        assert_thm1(&model, &CostSource::analytic(&tb));
    }
}

#[test]
fn thm1_mobilenet_prefix() {
    let model = zoo::mobilenet_v1(224, 1000).truncated(5);
    for nodes in [3usize, 4] {
        let tb = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));
        assert_thm1(&model, &CostSource::analytic(&tb));
    }
}

#[test]
fn thm1_holds_under_gbdt_oracle() {
    // Theorem 1 is about *whatever* cost oracle the DP consults — a learned
    // CE included. (The plan may differ from the analytic-oracle plan; the
    // optimality claim is relative to the oracle.)
    let cfg = TraceConfig { samples: 4_000, ..Default::default() };
    let params = GbdtParams { n_trees: 80, ..Default::default() };
    let (est, _) = Estimators::train_from_scratch(&cfg, &params);
    let est = std::sync::Arc::new(est);
    let model = zoo::tiny_chain(3, 12, 8);
    for nodes in [3usize, 4] {
        let tb = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));
        let cost = CostSource::gbdt(est.clone(), &tb);
        assert_thm1(&model, &cost);
    }
}

#[test]
fn thm1_pruning_is_lossless() {
    // The dynamic-threshold pruning must never change the result.
    let model = zoo::edgenet(16);
    for nodes in [3usize, 4, 5] {
        for gbps in [5.0, 0.5] {
            let tb = Testbed::new(nodes, Topology::Ps, Bandwidth::gbps(gbps));
            let cost = CostSource::analytic(&tb);
            let pruned = Dpp::with_config(
                &model,
                &cost,
                DppConfig { prune: true, ..Default::default() },
            )
            .plan();
            let unpruned = Dpp::with_config(
                &model,
                &cost,
                DppConfig { prune: false, ..Default::default() },
            )
            .plan();
            assert!(
                (pruned.est_cost - unpruned.est_cost).abs() <= 1e-12 * pruned.est_cost,
                "n={nodes} bw={gbps}"
            );
        }
    }
}

#[test]
fn parallel_memoized_dpp_is_bit_identical_across_zoo_and_conditions() {
    // The planner's speed knobs (wavefront-parallel search, shared query
    // memo with analytic bandwidth re-pricing) must be cost-transparent:
    // across the model zoo × {ring, star} testbeds × a bandwidth sweep, the
    // parallel+memoized search returns the serial unmemoized search's plan
    // cost, bit for bit. One store is shared across every combination, so
    // cross-testbed namespacing and the rescale path are both exercised.
    let store = flexpie::cost::MemoStore::shared();
    let models = [
        zoo::edgenet(16),
        zoo::mobilenet_v1(224, 1000).truncated(10),
        zoo::resnet18(224, 1000).truncated(8),
        zoo::tiny_chain(6, 16, 8),
    ];
    for model in &models {
        for topo in [Topology::Ring, Topology::Ps] {
            for gbps in [5.0, 1.0, 0.25] {
                let tb = Testbed::new(4, topo, Bandwidth::gbps(gbps));
                let serial = Dpp::with_config(
                    model,
                    &CostSource::analytic(&tb),
                    DppConfig { workers: 1, ..Default::default() },
                )
                .plan();
                let memo = CostSource::analytic(&tb).memoized(&store);
                let par = Dpp::with_config(
                    model,
                    &memo,
                    DppConfig { workers: 4, ..Default::default() },
                )
                .plan();
                assert_eq!(
                    par.est_cost.to_bits(),
                    serial.est_cost.to_bits(),
                    "{} {topo} {gbps} Gb/s: parallel+memo {} vs serial {}",
                    model.name,
                    par.est_cost,
                    serial.est_cost
                );
                assert_eq!(par.steps, serial.steps, "{} {topo} {gbps} Gb/s", model.name);
            }
        }
    }
    let stats = store.stats();
    assert!(stats.sync_rescales > 0, "bandwidth sweep never hit the rescale path: {stats}");
}

#[test]
fn dpp_beats_or_ties_restricted_planners_everywhere() {
    // Sanity corollary: restricting the search space can never help.
    let model = zoo::edgenet(16);
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(0.5));
    let cost = CostSource::analytic(&tb);
    let full = Dpp::new(&model, &cost).plan();
    for schemes in [
        vec![Scheme::InH],
        vec![Scheme::OutC],
        vec![Scheme::InH, Scheme::InW],
    ] {
        let restricted =
            Dpp::with_config(&model, &cost, DppConfig { schemes, ..Default::default() }).plan();
        assert!(full.est_cost <= restricted.est_cost + 1e-12);
    }
}
