//! Wire-fault end-to-end: the acceptance test for deterministic fault
//! injection plus replay recovery, on both fabrics.
//!
//! Three seeded single-fault-per-window [`FaultSchedule`]s run against
//!
//! * the **sim** fabric — [`run_faulted`] replays the schedule over the
//!   in-process mesh, and
//! * the **tcp** fabric — one in-thread daemon per node over real
//!   sockets, each wrapping its data plane in a `FaultExchange`, served
//!   through [`Server::start_process`] with a bounded replay budget.
//!
//! The bar is identical on both: `ok == requests` (no request left
//! behind), every delivered output bit-identical to the fault-free
//! single-node reference, delivery order preserved, and corrupted frames
//! caught by the checksum — surfaced as typed aborts and replayed, never
//! as wrong numerics. Each test prints a single-line `RESULT {...}` JSON
//! summary that CI's required `wire-chaos` job uploads.

use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::config::FaultExperiment;
use flexpie::model::{zoo, Model};
use flexpie::partition::{Plan, Scheme};
use flexpie::serve::{ServeConfig, Server};
use flexpie::transport::coord::ProcessCluster;
use flexpie::transport::daemon::{self, DaemonOpts};
use flexpie::transport::fault::{run_faulted, FaultDrillOutcome};
use flexpie::transport::registry::RegistryServer;
use flexpie::transport::tcp::TcpOpts;
use flexpie::util::bench::emit_result;
use flexpie::util::json::Json;

/// The fixed seeds CI runs as a required job.
const CI_SEEDS: [u64; 3] = [11, 23, 47];

fn experiment(seed: u64, fabric: &str) -> FaultExperiment {
    FaultExperiment { seed, fabric: fabric.into(), ..FaultExperiment::default() }
}

fn input_for(model: &Model, seed: u64) -> Tensor {
    let l0 = &model.layers[0];
    Tensor::random(l0.in_h, l0.in_w, l0.in_c, seed)
}

#[test]
fn sim_fabric_recovers_every_ci_seed_bit_identically() {
    let model = zoo::edgenet(16);
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let weights = WeightStore::for_model(&model, 5);
    let mut results: Vec<FaultDrillOutcome> = Vec::new();
    for &seed in &CI_SEEDS {
        let exp = experiment(seed, "sim");
        let schedule = exp.schedule();
        assert!(!schedule.is_empty(), "seed {seed}: empty schedule");
        let out = run_faulted(
            &model,
            &plan,
            &weights,
            &schedule,
            exp.requests,
            3_000 * (seed + 1),
            exp.replay_budget,
            Duration::from_millis(400),
        );
        out.verify().unwrap_or_else(|e| panic!("seed {seed}: {e} ({out})"));
        assert_eq!(out.ok, exp.requests, "seed {seed}: a request was left behind: {out}");
        assert_eq!(out.failed, 0, "seed {seed}: {out}");
        assert!(
            out.injected.corrupts >= 1,
            "seed {seed}: window 0 must corrupt a frame and the checksum must catch it: {out}"
        );
        results.push(out);
    }
    let sum = |f: fn(&FaultDrillOutcome) -> u64| results.iter().map(f).sum::<u64>();
    emit_result(vec![
        ("bench", Json::Str("fault_e2e_sim".into())),
        ("seeds", Json::arr(CI_SEEDS.iter().map(|&s| Json::Num(s as f64)))),
        ("requests", Json::Num(sum(|o| o.requests) as f64)),
        ("ok", Json::Num(sum(|o| o.ok) as f64)),
        ("failed", Json::Num(sum(|o| o.failed) as f64)),
        ("events_scripted", Json::Num(sum(|o| o.events as u64) as f64)),
        ("faults_injected", Json::Num(sum(|o| o.injected.total()) as f64)),
        ("corrupts_caught", Json::Num(sum(|o| o.injected.corrupts) as f64)),
        ("replay_attempts", Json::Num(sum(|o| o.replay_attempts) as f64)),
        ("mismatches", Json::Num(sum(|o| o.mismatches) as f64)),
    ]);
}

#[test]
fn tcp_fabric_recovers_every_ci_seed_bit_identically() {
    let model = zoo::edgenet(16);
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let (mut requests, mut ok, mut replays, mut attempts, mut failovers) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    for &seed in &CI_SEEDS {
        // fewer requests than the sim drill: every wire fault here costs a
        // real socket deadline, and window 0's corrupt still lands early
        let exp = FaultExperiment { requests: 8, ..experiment(seed, "tcp") };
        let schedule = exp.schedule();
        let registry = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_secs(5))
            .expect("registry bind");
        // short recv deadline: dropped frames must surface as typed
        // deadline aborts quickly enough for reinstall + replay to finish
        // inside the test budget, never as hangs
        let tcp = TcpOpts { recv_deadline: Duration::from_millis(1500), ..TcpOpts::default() };
        let mut daemons = Vec::new();
        for node in 0..exp.nodes as u32 {
            let mut opts = DaemonOpts::new(node, registry.addr());
            opts.tcp = tcp;
            // every daemon carries the same schedule; each injects only
            // the events whose `src` matches its generation rank
            opts.fault = Some(schedule.clone());
            daemons.push(std::thread::spawn(move || daemon::run(opts)));
        }
        let mut pc = ProcessCluster::connect(registry.addr(), exp.nodes, Duration::from_secs(30))
            .expect("cluster bring-up");
        pc.infer_deadline = Duration::from_secs(10);
        pc.install(&model, &plan, seed).expect("plan install");

        let ws = WeightStore::for_model(&model, seed);
        let server = Server::start_process(
            pc,
            ServeConfig {
                max_batch: 1,
                batch_window: Duration::ZERO,
                queue_depth: 64,
                pipeline_depth: 1,
                replay_budget: exp.replay_budget,
            },
        );
        let inputs: Vec<Tensor> =
            (0..exp.requests).map(|i| input_for(&model, 7_000 * (seed + 1) + i)).collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| server.submit(t.clone()).expect("admission failed"))
            .collect();
        let mut last_seq: Option<u64> = None;
        for (i, (input, rx)) in inputs.iter().zip(rxs).enumerate() {
            let resp = rx
                .recv()
                .unwrap_or_else(|_| panic!("seed {seed}: request {i} failed over the wire"));
            let reference = run_reference(&model, &ws, input);
            assert_eq!(
                reference.max_abs_diff(&resp.output),
                0.0,
                "seed {seed}: request {i} output diverged from the fault-free reference"
            );
            assert!(
                last_seq.map_or(true, |p| resp.seq > p),
                "seed {seed}: request {i} delivered out of order"
            );
            last_seq = Some(resp.seq);
            ok += 1;
        }
        requests += exp.requests;
        let stats = server.shutdown();
        assert_eq!(stats.failed_on_dead_cluster, 0, "seed {seed}: a request was failed back");
        // window 0 always corrupts a frame, the checksum kills that
        // generation, and the router must have replayed through it
        assert!(
            stats.process_failovers >= 1,
            "seed {seed}: the scripted corruption never aborted a generation"
        );
        assert!(
            stats.replayed_on_dead_cluster >= 1,
            "seed {seed}: recovery completed no replayed request"
        );
        assert!(stats.replay_attempts >= 1, "seed {seed}: no replay was attempted");
        replays += stats.replayed_on_dead_cluster;
        attempts += stats.replay_attempts;
        failovers += stats.process_failovers;
        drop(daemons); // threads exit with the Shutdown sent by the server
    }
    emit_result(vec![
        ("bench", Json::Str("fault_e2e_tcp".into())),
        ("seeds", Json::arr(CI_SEEDS.iter().map(|&s| Json::Num(s as f64)))),
        ("requests", Json::Num(requests as f64)),
        ("ok", Json::Num(ok as f64)),
        ("failed", Json::Num(0.0)),
        ("replays", Json::Num(replays as f64)),
        ("replay_attempts", Json::Num(attempts as f64)),
        ("failovers", Json::Num(failovers as f64)),
        ("mismatches", Json::Num(0.0)),
    ]);
}
