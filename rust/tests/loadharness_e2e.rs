//! End-to-end load-harness test: suite A1 (the deterministic baseline)
//! driven exactly the way CI drives it — real `flexpie-load agent`
//! processes over TCP into an in-process server — plus the `flexpie-load
//! suite` CLI surface and its `RESULT` line contract.

use std::process::Command;

use flexpie::bench::harness::{self, HarnessOpts};
use flexpie::util::bench::result_line;
use flexpie::util::json::{self, Json};

fn opts() -> HarnessOpts {
    HarnessOpts {
        load_bin: env!("CARGO_BIN_EXE_flexpie-load").to_string(),
        node_bin: env!("CARGO_BIN_EXE_flexpie-node").to_string(),
        fast: true,
        artifact_dir: None,
    }
}

fn a1() -> harness::SuiteSpec {
    harness::suites(true)
        .into_iter()
        .find(|s| s.name == "a1_baseline")
        .expect("a1_baseline in the suite list")
}

#[test]
fn a1_serves_every_request_bit_exactly() {
    let spec = a1();
    let report = harness::run_suite(&spec, &opts()).expect("a1 must pass its gates");
    // the determinism contract: queue ≥ schedule ⇒ nothing shed, nothing
    // failed, every reply bit-identical to the single-node reference
    let total = spec.agents as u64 * spec.requests_per_agent as u64;
    assert_eq!(report.sent, total);
    assert_eq!(report.ok, total, "ok != requests: {report:?}");
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(report.mismatches, 0, "a reply diverged from the reference");
    assert_eq!(report.hist.count(), total);
    assert!(report.goodput_rps > 0.0);
    assert!(report.queue_peak >= 1, "traffic never touched the queue");
    // tracing is always on: every served request left a merged span tree,
    // and with no chaos every tree passes nesting + conservation (the
    // run_suite gate enforces this too — assert it here so a gate
    // relaxation cannot silently drop the contract)
    assert!(report.traces >= total, "missing span trees: {}", report.traces);
    assert_eq!(report.trace_well_formed, report.traces);
    assert_eq!(report.queue_hist.count(), report.traces);
    assert_eq!(report.service_hist.count(), report.traces);
}

#[test]
fn shed_counters_conserve_under_forced_overload() {
    // a deliberately undersized queue under a burst: some submissions must
    // come back Denied(queue full), and the per-reason server counters must
    // equal the agents' wire observations — run_suite's conservation gate
    // (server shed == agent shed, reason by reason) enforces exactly that,
    // so this test passing with report.shed > 0 is the e2e conservation
    // proof for the non-trivial case
    let spec = harness::SuiteSpec {
        name: "shed_conservation",
        mode: harness::Mode::InProc { pipeline_depth: 1 },
        agents: 2,
        requests_per_agent: 16,
        offered: harness::Offered::Fixed(flexpie::loadgen::ArrivalProcess::Burst {
            base_hz: 50.0,
            burst_hz: 4000.0,
            period_s: 0.05,
            duty: 0.8,
        }),
        seed: 77,
        slo: std::time::Duration::from_millis(250),
        queue_depth: Some(1),
        deterministic: false,
        warmup: 0.0,
    };
    let report = harness::run_suite(&spec, &opts()).expect("gates must hold under overload");
    assert_eq!(report.ok + report.shed + report.failed, report.sent, "conservation broke");
    assert!(report.shed > 0, "queue_depth 1 under a 4 kHz burst never shed — suspicious");
}

#[test]
fn warmup_trims_histogram_but_not_conservation() {
    // same A1 shape with a 25% warm-up: conservation still covers the full
    // schedule, but the histogram population shrinks by exactly the trim
    let mut spec = a1();
    spec.warmup = 0.25;
    let report = harness::run_suite(&spec, &opts()).expect("warmed-up a1 must pass its gates");
    let total = spec.agents as u64 * spec.requests_per_agent as u64;
    assert_eq!(report.sent, total);
    assert_eq!(report.ok, total);
    let expected_trim =
        spec.agents as u64 * (spec.requests_per_agent as f64 * spec.warmup).floor() as u64;
    assert_eq!(report.trimmed, expected_trim, "trim must be the configured leading fraction");
    assert_eq!(report.hist.count() + report.trimmed, report.ok);
    // the RESULT line carries the flag so a trimmed run can never pass as
    // an untrimmed one
    let v = report.to_json();
    assert_eq!(v.req("warmup").unwrap().as_f64(), Some(0.25));
    assert_eq!(v.req("trimmed").unwrap().as_f64(), Some(expected_trim as f64));
}

#[test]
fn a1_result_json_is_well_formed() {
    let report = harness::run_suite(&a1(), &opts()).expect("a1 must pass its gates");
    let line = result_line(&report.to_json());
    assert!(line.starts_with("RESULT {"));
    assert_eq!(line.lines().count(), 1, "RESULT must stay one grep-able line");
    let v = json::parse(line.strip_prefix("RESULT ").unwrap()).expect("RESULT body parses");

    // every declared percentile present, numeric and monotone non-decreasing
    let pct = ["p50_us", "p90_us", "p99_us", "p999_us"];
    let mut prev = 0.0f64;
    for key in pct {
        let p = v
            .req(key)
            .unwrap_or_else(|e| panic!("missing {key}: {e}"))
            .as_f64()
            .unwrap_or_else(|| panic!("{key} not numeric"));
        assert!(p >= prev, "{key} = {p} < previous percentile {prev}");
        prev = p;
    }
    for key in ["suite", "mode", "sent", "ok", "slo_violation_frac", "goodput_rps"] {
        assert!(v.req(key).is_ok(), "missing field {key}");
    }
    assert_eq!(v.req("suite").unwrap().as_str(), Some("a1_baseline"));
    assert_eq!(v.req("slo_violation_frac").unwrap().as_f64(), Some(0.0));
}

#[test]
fn suite_cli_emits_the_result_contract() {
    // the exact surface CI scrapes: `flexpie-load suite --suite a1_baseline`
    // on a fast profile, one RESULT line on stdout
    let out = Command::new(env!("CARGO_BIN_EXE_flexpie-load"))
        .args(["suite", "--suite", "a1_baseline"])
        .args(["--node-bin", env!("CARGO_BIN_EXE_flexpie-node")])
        .env("FLEXPIE_BENCH_FAST", "1")
        .output()
        .expect("run flexpie-load suite");
    assert!(
        out.status.success(),
        "suite exited with {}: {}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).expect("utf-8 stdout");
    let results: Vec<&str> =
        stdout.lines().filter(|l| l.starts_with("RESULT ")).collect();
    assert_eq!(results.len(), 1, "expected exactly one RESULT line:\n{stdout}");
    let v = json::parse(results[0].strip_prefix("RESULT ").unwrap()).expect("parses");
    assert_eq!(v.req("suite").unwrap().as_str(), Some("a1_baseline"));
    let sent = v.req("sent").unwrap().as_f64().unwrap();
    let ok = v.req("ok").unwrap().as_f64().unwrap();
    assert_eq!(sent, ok, "deterministic suite shed traffic");
    assert!(matches!(v.req("mismatches").unwrap(), Json::Num(n) if *n == 0.0));
}
