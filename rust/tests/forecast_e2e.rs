//! Forecast end-to-end: the acceptance test for the telemetry & forecasting
//! subsystem.
//!
//! The controller here is *never* shown the condition trace. The world is
//! hidden inside a [`TelemetrySource`]: passive probes on the traffic the
//! cluster moves, an active low-rate prober, and heartbeat/compute sweeps
//! produce samples; a ring-buffer store aggregates them; the forecaster
//! (EWMA level + trend) projects each series a few batch boundaries ahead;
//! and the background planner pre-warms the projected condition cell — so
//! when the diurnal dip actually lands, its replan is a **forecast-warmed
//! cache hit** with zero inline replans and no boundary rendezvous.
//!
//! `diurnal_dip_replan_is_forecast_warmed_through_measured_telemetry`
//! prints a single-line `RESULT {...}` JSON summary that CI uploads as an
//! artifact (forecast hit/miss counters, mean horizon error in quantized
//! buckets, boundary-stall percentiles).

use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::config::ForecastExperiment;
use flexpie::elastic::{ConditionTrace, ElasticConfig, ElasticFrontend};
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::plan_for_testbed;
use flexpie::serve::{ServeConfig, Server};
use flexpie::telemetry::{TelemetryConfig, TelemetrySource};
use flexpie::util::bench::emit_result;
use flexpie::util::json::Json;

fn base(nodes: usize) -> Testbed {
    Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0))
}

#[test]
fn diurnal_dip_replan_is_forecast_warmed_through_measured_telemetry() {
    // One compressed day of diurnal bandwidth drift (100% → 40% → 100%),
    // fed through the full telemetry path: world → probes → store →
    // forecaster → pre-warm. No direct trace read anywhere downstream.
    let exp = ForecastExperiment::default();
    let model = zoo::edgenet(16);
    let base = base(4);
    let world = exp.world(4).expect("valid profile");
    let source = TelemetrySource::new(world, &base, exp.telemetry_config());
    let store = source.store();
    let mut fe = ElasticFrontend::start_with_source(
        model,
        base,
        Box::new(source),
        exp.elastic_config(),
    );

    for k in 0..exp.boundaries() {
        let vt = k as f64 * exp.boundary_dt;
        let d = fe.acquire(vt);
        assert_eq!(d.nodes, 4, "drift must never drop a node (vt={vt})");
        assert_eq!(d.leader, 0);
        assert!(d.cost_per_item > 0.0);
        // deterministic rendezvous: pre-warms requested at this boundary
        // complete before the next one, so hit attribution cannot race
        fe.quiesce();
    }
    let ingest = store.stats();
    let (m, stalls) = fe.finish();

    // the ingestion layer actually measured the world (probes, sweeps)
    assert!(ingest.bandwidth_samples as usize >= exp.boundaries(), "{ingest}");
    assert!(ingest.liveness_sweeps as usize >= exp.boundaries(), "{ingest}");
    assert!(ingest.compute_samples > 0, "{ingest}");

    // the acceptance property: the dip's regime shifts were pre-planned
    // from forecasts and served warm — never inline, never a rendezvous
    assert!(m.forecasts >= 1, "no pre-warm was ever requested: {m}");
    assert!(m.forecast_plans >= 1, "no forecast cell was ever planned: {m}");
    assert!(
        m.forecast_hits >= 1,
        "the dip's replan was not a forecast-warmed cache hit: {m}"
    );
    assert_eq!(m.inline_replans, 0, "a boundary ran a DPP search inline: {m}");
    assert_eq!(m.failovers, 0, "drift must never rendezvous as a failover: {m}");
    assert_eq!(m.stale_plan_boundaries, 0, "{m}");
    assert_eq!(m.checks, exp.boundaries() as u64);
    // matured projections were scored against reality
    assert!(m.forecast_evals >= 1, "{m}");

    // zero boundary stall at the dip: every acquisition is a sample plus
    // one atomic epoch load — even a noisy CI box stays far below search
    // time at the median
    assert_eq!(stalls.count, exp.boundaries());
    assert!(
        stalls.p50 < Duration::from_millis(20),
        "boundaries are stalling on planning: {stalls}"
    );

    emit_result(vec![
        ("suite", Json::Str("forecast_e2e".into())),
        ("boundaries", Json::Num(m.checks as f64)),
        ("bandwidth_samples", Json::Num(ingest.bandwidth_samples as f64)),
        ("active_probes", Json::Num(ingest.active_probes as f64)),
        ("forecasts", Json::Num(m.forecasts as f64)),
        ("forecast_plans", Json::Num(m.forecast_plans as f64)),
        ("forecast_hits", Json::Num(m.forecast_hits as f64)),
        ("forecast_misses", Json::Num(m.forecast_misses as f64)),
        ("forecast_hit_rate", Json::Num(m.forecast_hit_rate())),
        ("forecast_mean_bucket_err", Json::Num(m.forecast_mean_bucket_err())),
        ("inline_replans", Json::Num(m.inline_replans as f64)),
        ("stale_plan_boundaries", Json::Num(m.stale_plan_boundaries as f64)),
        ("stall_p50_us", Json::Num(stalls.p50.as_secs_f64() * 1e6)),
        ("stall_p99_us", Json::Num(stalls.p99.as_secs_f64() * 1e6)),
    ]);
}

/// A deterministic staircase descent (no trig, no RNG): non-overlapping
/// absolute-factor windows stepping the bandwidth down 5% per virtual
/// second — the controlled drift the failover test rides.
fn staircase(nodes: usize) -> ConditionTrace {
    ConditionTrace::stable(nodes)
        .with_bandwidth_dip(1.0, 2.0, 0.95)
        .with_bandwidth_dip(2.0, 3.0, 0.90)
        .with_bandwidth_dip(3.0, 4.0, 0.85)
        .with_bandwidth_dip(4.0, 5.0, 0.80)
        .with_bandwidth_dip(5.0, f64::INFINITY, 0.75)
}

#[test]
fn measured_failover_during_a_forecast_drift_stays_warm() {
    // A node dies mid-descent, observed only through heartbeats. The
    // forecaster has been pre-speculating n−1 cells at the *forecast*
    // bandwidth, so both the failover and the post-failover cell shift are
    // served from the warm cache — the cold-failover gap this subsystem
    // closes.
    let model = zoo::edgenet(16);
    let base = base(4);
    let world = staircase(4).with_outage(2, 3.75, f64::INFINITY);
    let source = TelemetrySource::new(world, &base, TelemetryConfig::default());
    let ecfg = ElasticConfig {
        forecast: Some(flexpie::telemetry::ForecastConfig::default()),
        cache_capacity: 64,
        ..ElasticConfig::default()
    };
    let mut fe = ElasticFrontend::start_with_source(model, base, Box::new(source), ecfg);
    let mut nodes_seen = Vec::new();
    for k in 0..20 {
        let d = fe.acquire(k as f64 * 0.5);
        nodes_seen.push(d.nodes);
        if d.nodes == 3 {
            assert_eq!(d.alive, vec![true, true, false, true]);
            assert_eq!(d.leader, 0, "a worker loss must not move leadership");
        }
        fe.quiesce();
    }
    assert!(nodes_seen.contains(&3), "the outage never reached the measured path");
    assert_eq!(nodes_seen[..7], vec![4; 7], "heartbeat killed the node early");
    let (m, _) = fe.finish();
    assert!(m.failovers >= 1, "{m}");
    assert!(
        m.speculative_hits >= 1,
        "measured failover was not served from the speculative cache: {m}"
    );
    assert!(m.forecasts >= 1, "{m}");
    assert_eq!(m.inline_replans, 0, "{m}");
    assert_eq!(m.stale_plan_boundaries, 0, "{m}");
}

#[test]
fn telemetry_server_serves_bit_exact_and_detects_a_measured_collapse() {
    // The full serving path on measured conditions: outputs stay
    // bit-identical to the single-node reference, every request is
    // accounted, and a mid-stream bandwidth collapse reaches the monitor
    // purely through the passive traffic probe.
    let model = zoo::edgenet(16);
    let base = base(4);
    let plan0 = plan_for_testbed(&model, &base);
    let c0 = engine::evaluate(&model, &plan0, &base).total;
    let world = ConditionTrace::stable(4).with_bandwidth_dip(2.5 * c0, f64::INFINITY, 0.1);
    let server = Server::start_telemetry(
        model.clone(),
        WeightStore::for_model(&model, 5),
        base,
        world,
        TelemetryConfig::default(),
        ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 16,
            ..ServeConfig::default()
        },
        ElasticConfig::default(),
    );
    let ws = WeightStore::for_model(&model, 5);
    let n_requests = 10u64;
    for i in 0..n_requests {
        let input = Tensor::random(16, 16, 3, 4000 + i);
        let reference = run_reference(&model, &ws, &input);
        let resp = server.infer(input).expect("request lost");
        assert_eq!(
            reference.max_abs_diff(&resp.output),
            0.0,
            "request {i} output diverged on the measured path"
        );
        assert_eq!(resp.nodes, 4);
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, n_requests);
    let m = stats.adaptation.expect("measured path reports adaptation");
    assert_eq!(m.checks, n_requests);
    assert!(
        m.degraded_checks >= 1,
        "the collapse never reached the monitor through the probes: {m}"
    );
    assert_eq!(m.inline_replans, 0, "{m}");
}
