//! Process-mode end-to-end: real OS processes, real sockets, real SIGKILL.
//!
//! Each test spawns a registry process (`flexpie-ctl registry`) and a set
//! of node daemon processes (`flexpie-node`) on localhost, then drives
//! them with an in-test [`ProcessCluster`]. The acceptance bars:
//!
//! 1. **Bit-exactness** — outputs over the wire equal the in-process
//!    single-node reference exactly, across zoo models and plan schemes
//!    (the frame codec carries f32 bit patterns, and every output element
//!    still has exactly one accumulation order).
//! 2. **`kill -9` chaos** — SIGKILLing a *worker* and SIGKILLing the
//!    *leader* both surface as explicit failed inferences (never a hang,
//!    never a silent drop), the coordinator reinstalls on the survivors,
//!    and the retried inference is bit-identical — the PR 4 chaos
//!    invariants, now with nothing simulated about the failure.
//! 3. **Order** — delivered sequence numbers strictly increase.
//!
//! `sigkill_worker_and_leader_chaos_audit` prints the single-line
//! `RESULT {...}` JSON that CI's required `process-e2e` job uploads.

use std::io::{BufRead, BufReader};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::model::{zoo, Model};
use flexpie::partition::{Plan, Scheme};
use flexpie::serve::{ServeConfig, Server};
use flexpie::transport::coord::{InferOutcome, ProcessCluster};
use flexpie::util::bench::emit_result;
use flexpie::util::json::Json;

/// A child process that is SIGKILLed (and reaped) when dropped — tests
/// never leak daemons, even on panic. Keeps the stdout pipe open so the
/// child can never trip over a closed descriptor.
struct Proc {
    child: Child,
    _out: Option<BufReader<ChildStdout>>,
}

impl Proc {
    fn sigkill(&mut self) {
        let _ = self.child.kill(); // SIGKILL on unix — no goodbye frames
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.sigkill();
    }
}

/// Spawn a child and wait for its one-line `PREFIX …` boot banner.
fn spawn_banner(mut cmd: Command, prefix: &str) -> (Proc, String) {
    let mut child = cmd.stdout(Stdio::piped()).spawn().expect("spawn child process");
    let mut out = BufReader::new(child.stdout.take().expect("stdout piped"));
    let mut line = String::new();
    out.read_line(&mut line).expect("read boot banner");
    let rest = line
        .trim_end()
        .strip_prefix(prefix)
        .unwrap_or_else(|| panic!("expected {prefix:?} banner, got {line:?}"))
        .to_string();
    (Proc { child, _out: Some(out) }, rest)
}

fn spawn_registry() -> (Proc, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_flexpie-ctl"));
    cmd.args(["registry", "--ttl-ms", "600"]);
    spawn_banner(cmd, "REGISTRY ")
}

fn spawn_daemon(node: u32, registry: &str) -> Proc {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_flexpie-node"));
    cmd.args(["--node", &node.to_string(), "--registry", registry]);
    // the READY banner doubles as the liveness barrier
    let (proc_, _) = spawn_banner(cmd, "READY ");
    proc_
}

fn connect(registry: &str, n: usize) -> ProcessCluster {
    ProcessCluster::connect(registry, n, Duration::from_secs(30))
        .expect("cluster bring-up within deadline")
}

fn input_for(model: &Model, seed: u64) -> Tensor {
    let l0 = &model.layers[0];
    Tensor::random(l0.in_h, l0.in_w, l0.in_c, seed)
}

/// Run `n` inferences, asserting every one completes bit-identically.
fn assert_exact(pc: &mut ProcessCluster, model: &Model, seed: u64, n: u64) {
    let ws = WeightStore::for_model(model, seed);
    for i in 0..n {
        let input = input_for(model, 0xE2E + i);
        let reference = run_reference(model, &ws, &input);
        match pc.infer(&input).expect("coordinator alive") {
            InferOutcome::Done(run) => {
                assert_eq!(
                    reference.max_abs_diff(&run.output),
                    0.0,
                    "{}: wire output differs from reference (request {i})",
                    model.name
                );
            }
            InferOutcome::Failed { dead, .. } => {
                panic!("{}: healthy cluster failed request {i} (dead={dead:?})", model.name)
            }
        }
    }
}

#[test]
fn process_cluster_is_bit_identical_across_zoo() {
    let (_reg, registry) = spawn_registry();
    let _daemons: Vec<Proc> = (0..3).map(|i| spawn_daemon(i, &registry)).collect();
    let mut pc = connect(&registry, 3);

    // three zoo shapes at edge scale, two schemes — each install replaces
    // the previous generation on live daemons
    let sweep: Vec<(Model, Scheme)> = vec![
        (zoo::edgenet(16), Scheme::InH),
        (zoo::tiny_chain(4, 16, 8), Scheme::OutC),
        (zoo::mobilenet_v1(32, 10).truncated(5), Scheme::InH),
    ];
    for (model, scheme) in &sweep {
        let plan = Plan::uniform(*scheme, model.n_layers());
        pc.install(model, &plan, 31).expect("plan install");
        assert_eq!(pc.nodes(), 3);
        assert_exact(&mut pc, model, 31, 2);
    }
    pc.shutdown();
}

/// One kill drill: submit inferences, SIGKILL `victim` after the first
/// completes, and audit the chaos invariants. Returns
/// `(ok, failed_reported)`.
fn kill_drill(
    pc: &mut ProcessCluster,
    model: &Model,
    seed: u64,
    victim: &mut Proc,
    victim_id: u32,
    requests: u64,
) -> (u64, u64) {
    let ws = WeightStore::for_model(model, seed);
    let (mut ok, mut failed) = (0u64, 0u64);
    let mut last_seq: Option<u64> = None;
    let mut killed = false;
    let mut i = 0u64;
    while i < requests {
        let input = input_for(model, 0x51 + i);
        let reference = run_reference(model, &ws, &input);
        match pc.infer(&input).expect("coordinator alive") {
            InferOutcome::Done(run) => {
                assert_eq!(
                    reference.max_abs_diff(&run.output),
                    0.0,
                    "request {i}: output differs from reference"
                );
                // order preserved: delivered sequence numbers increase
                assert!(last_seq.map_or(true, |p| run.seq > p), "seq regressed");
                last_seq = Some(run.seq);
                ok += 1;
                i += 1;
                if !killed {
                    victim.sigkill();
                    killed = true;
                }
            }
            InferOutcome::Failed { dead, .. } => {
                // explicit, attributed failure — never a silent drop
                failed += 1;
                assert!(failed <= 10, "cluster kept failing after reinstalls");
                assert!(killed, "failure before any fault was injected");
                if let Some(d) = dead {
                    assert_eq!(d, victim_id, "failure blamed the wrong node");
                }
                pc.reinstall(dead.or(Some(victim_id))).expect("survivors reinstall");
                // `i` not advanced: the same input retries bit-identically
            }
        }
    }
    assert!(killed, "drill never injected its fault");
    (ok, failed)
}

#[test]
fn sigkill_worker_and_leader_chaos_audit() {
    let model = zoo::edgenet(16);
    let plan = Plan::uniform(Scheme::InH, model.n_layers());

    // drill 1: SIGKILL a worker (highest id — never the leader)
    let (_reg_w, registry_w) = spawn_registry();
    let mut daemons_w: Vec<Proc> = (0..3).map(|i| spawn_daemon(i, &registry_w)).collect();
    let mut pc = connect(&registry_w, 3);
    pc.install(&model, &plan, 47).expect("install");
    assert_eq!(pc.leader(), 0);
    let mut worker = daemons_w.pop().unwrap(); // node 2
    let (ok_w, failed_w) = kill_drill(&mut pc, &model, 47, &mut worker, 2, 4);
    assert!(failed_w >= 1, "worker SIGKILL was never observed");
    assert_eq!(pc.nodes(), 2, "dead worker still in the membership");
    assert_eq!(pc.leader(), 0, "worker death must not move the leader");
    pc.shutdown();
    drop(daemons_w);

    // drill 2: SIGKILL the leader — no node is immortal
    let (_reg_l, registry_l) = spawn_registry();
    let mut daemons_l: Vec<Proc> = (0..3).map(|i| spawn_daemon(i, &registry_l)).collect();
    let mut pc = connect(&registry_l, 3);
    pc.install(&model, &plan, 53).expect("install");
    let mut leader = daemons_l.remove(0); // node 0 — the current leader
    let (ok_l, failed_l) = kill_drill(&mut pc, &model, 53, &mut leader, 0, 4);
    assert!(failed_l >= 1, "leader SIGKILL was never observed");
    assert_eq!(pc.nodes(), 2);
    assert_eq!(pc.leader(), 1, "lowest surviving id must take over");
    pc.shutdown();
    drop(daemons_l);

    // the audit line CI uploads: every request ok or explicitly failed,
    // zero lost, zero mismatches (mismatches panic above)
    emit_result(vec![
        ("bench", Json::Str("process_e2e_sigkill".into())),
        ("requests", Json::Num((ok_w + ok_l) as f64)),
        ("ok", Json::Num((ok_w + ok_l) as f64)),
        ("failed_reported", Json::Num((failed_w + failed_l) as f64)),
        ("requests_lost", Json::Num(0.0)),
        ("mismatches", Json::Num(0.0)),
        ("worker_kills", Json::Num(1.0)),
        ("leader_kills", Json::Num(1.0)),
    ]);
}

#[test]
fn served_sigkill_leader_replays_in_flight_to_completion() {
    // The serving-layer twin of the SIGKILL drills: the router owns the
    // recovery loop, so a leader killed mid-stream is invisible to
    // clients — every request completes bit-identically and in order, and
    // the router's replay counters prove the path was exercised.
    let model = zoo::edgenet(16);
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let (_reg, registry) = spawn_registry();
    let mut daemons: Vec<Proc> = (0..3).map(|i| spawn_daemon(i, &registry)).collect();
    let mut pc = connect(&registry, 3);
    pc.install(&model, &plan, 71).expect("install");
    pc.infer_deadline = Duration::from_secs(10);
    let ws = WeightStore::for_model(&model, 71);

    let server = Server::start_process(
        pc,
        ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 16,
            pipeline_depth: 1,
            replay_budget: 4,
        },
    );
    let inputs: Vec<Tensor> = (0..6).map(|i| input_for(&model, 0x9E + i)).collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|t| server.submit(t.clone()).expect("admission failed"))
        .collect();

    let mut last_seq: Option<u64> = None;
    let mut killed = false;
    for (i, (input, rx)) in inputs.iter().zip(rxs).enumerate() {
        let resp = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i} was failed back to the client"));
        let reference = run_reference(&model, &ws, input);
        assert_eq!(
            reference.max_abs_diff(&resp.output),
            0.0,
            "request {i} output diverged from the reference"
        );
        assert!(last_seq.map_or(true, |p| resp.seq > p), "request {i} out of order");
        last_seq = Some(resp.seq);
        if !killed {
            daemons[0].sigkill(); // node 0 — the current leader
            killed = true;
        }
    }

    let stats = server.shutdown();
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.failed_on_dead_cluster, 0, "a request was failed back");
    assert!(stats.process_failovers >= 1, "leader SIGKILL was never observed");
    assert!(stats.replayed_on_dead_cluster >= 1, "no request rode the replay path");
    assert!(stats.replay_attempts >= stats.replayed_on_dead_cluster);
}

#[test]
fn registry_survives_daemon_churn() {
    // daemons come and go; resolve() must track the live set through TTL
    // expiry, and a rebuilt cluster on the survivors must still be exact
    let (_reg, registry) = spawn_registry();
    let mut daemons: Vec<Proc> = (0..3).map(|i| spawn_daemon(i, &registry)).collect();
    let mut pc = connect(&registry, 3);
    let model = zoo::edgenet(16);
    let plan = Plan::uniform(Scheme::OutC, model.n_layers());
    pc.install(&model, &plan, 61).expect("install");
    assert_exact(&mut pc, &model, 61, 1);

    // kill one daemon and wait out its lease: the registry itself — not
    // the coordinator's ban list — must report it gone
    daemons.pop().unwrap().sigkill();
    std::thread::sleep(Duration::from_millis(900)); // ttl 600ms + renewal slack
    let live = flexpie::transport::registry::resolve(&registry).expect("resolve");
    assert_eq!(live.len(), 2, "expired lease still resolved: {live:?}");

    pc.reinstall(None).expect("reinstall on survivors");
    assert_eq!(pc.nodes(), 2);
    assert_exact(&mut pc, &model, 61, 1);
    pc.shutdown();
}
