//! End-to-end elastic serving under dynamic conditions: the acceptance test
//! for the runtime-adaptation subsystem.
//!
//! A [`Server`] on the elastic path is driven through a deterministic
//! node-churn trace. The controller must detect the failure at a batch
//! boundary, swap to a surviving-cluster (n−1) plan before the next batch,
//! lose no request, keep every output bit-identical to the single-node
//! reference, and — when the node rejoins — restore the original plan from
//! the warm cache. Replan count and cache hit rate ride back on the router
//! stats.

use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::elastic::{ConditionTrace, ElasticConfig, ElasticController, ElasticFrontend};
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::plan_for_testbed;
use flexpie::serve::{ServeConfig, Server};

/// One-request-per-batch config: batch boundaries (and therefore adaptation
/// points) land exactly between consecutive requests, making virtual-time
/// arithmetic in the tests deterministic.
fn per_request_batches() -> ServeConfig {
    ServeConfig {
        max_batch: 1,
        batch_window: Duration::ZERO,
        queue_depth: 16,
        ..ServeConfig::default()
    }
}

#[test]
fn server_survives_node_churn_without_losing_requests() {
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));

    // Virtual-time bookkeeping: each batch advances the clock by the
    // predicted per-item cost of the plan it ran. With per-request batches,
    // batch k is checked at vt = sum of costs of batches 0..k.
    let plan4 = plan_for_testbed(&model, &base);
    let c4 = engine::evaluate(&model, &plan4, &base).total;
    let tb3 = base.subset(&[true, true, false, true]);
    let plan3 = plan_for_testbed(&model, &tb3);
    let c3 = engine::evaluate(&model, &plan3, &tb3).total;

    // Node 2 dies during the third batch's window and rejoins after roughly
    // three degraded batches (costs after the failover are c3 per batch).
    let down_at = 2.5 * c4;
    let up_at = 3.0 * c4 + 2.5 * c3;
    let trace = ConditionTrace::stable(4).with_outage(2, down_at, up_at);

    let server = Server::start_elastic(
        model.clone(),
        WeightStore::for_model(&model, 5),
        base,
        trace,
        per_request_batches(),
        ElasticConfig::default(),
    );

    let ws = WeightStore::for_model(&model, 5);
    let n_requests = 10u64;
    let mut nodes_seen = Vec::new();
    for i in 0..n_requests {
        let input = Tensor::random(16, 16, 3, 1000 + i);
        let reference = run_reference(&model, &ws, &input);
        // sequential infer → exactly one batch per request, in order
        let resp = server.infer(input).expect("request lost");
        assert_eq!(
            reference.max_abs_diff(&resp.output),
            0.0,
            "request {i} output diverged after adaptation"
        );
        assert!(resp.virtual_time > 0.0);
        assert_eq!(resp.leader, 0, "worker churn must not move leadership");
        nodes_seen.push(resp.nodes);
    }

    // Batches 0..=2 run healthy at vt = 0, c4, 2c4 (< down_at); batch 3 at
    // vt = 3·c4 ≥ down_at sees the outage: the swap lands within one batch
    // boundary of the failure.
    assert_eq!(&nodes_seen[..3], &[4, 4, 4], "pre-failure batches degraded early");
    assert_eq!(nodes_seen[3], 3, "failover missed its batch boundary");
    assert!(
        nodes_seen[3..].contains(&4),
        "node rejoin never observed: {nodes_seen:?}"
    );
    // no request was dropped and none reordered
    assert_eq!(nodes_seen.len(), n_requests as usize);

    let stats = server.shutdown();
    assert_eq!(stats.requests, n_requests);
    let m = stats.adaptation.expect("elastic path reports adaptation metrics");
    assert_eq!(m.checks, n_requests, "one condition check per batch");
    assert!(m.failovers >= 2, "expected down + up failovers: {m}");
    // the 3-node cell was a cold miss; the rejoin must hit the cached
    // 4-node plan
    assert!(m.replans >= 2, "degraded cell never planned: {m}");
    assert!(m.cache_hits >= 1, "rejoin did not reuse the warm plan: {m}");
    assert!(m.cache_hit_rate() > 0.0);
    assert!(
        m.speculative_hits >= 1,
        "worker loss was not served from the speculative cache: {m}"
    );
    assert_eq!(m.leader_handoffs, 0, "worker churn must not hand off leadership: {m}");
}

#[test]
fn server_survives_leader_loss_in_lockstep() {
    // The leader (node 0) dies permanently mid-stream. Lockstep leaves
    // nothing in flight at a boundary, so no request fails: the next batch
    // simply executes with rank 1 elected leader, outputs stay bit-exact,
    // and the failover is served from the speculative plan cache.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let plan4 = plan_for_testbed(&model, &base);
    let c4 = engine::evaluate(&model, &plan4, &base).total;
    let trace = ConditionTrace::stable(4).with_outage(0, 2.5 * c4, f64::INFINITY);

    let server = Server::start_elastic(
        model.clone(),
        WeightStore::for_model(&model, 5),
        base.clone(),
        trace,
        per_request_batches(),
        ElasticConfig::default(),
    );
    let ws = WeightStore::for_model(&model, 5);
    let n_requests = 8u64;
    let mut seen = Vec::new();
    for i in 0..n_requests {
        let input = Tensor::random(16, 16, 3, 7000 + i);
        let reference = run_reference(&model, &ws, &input);
        let resp = server.infer(input).expect("request lost");
        assert_eq!(
            reference.max_abs_diff(&resp.output),
            0.0,
            "request {i} output diverged after leader failover"
        );
        assert_eq!(resp.seq, i, "completion order broken");
        seen.push((resp.nodes, resp.leader));
    }
    // batches 0..=2 run healthy (vt = 0, c4, 2c4 < 2.5·c4) under leader 0;
    // batch 3 at vt = 3c4 sees the dead leader and elects rank 1
    assert_eq!(&seen[..3], &[(4, 0), (4, 0), (4, 0)], "degraded early");
    for (i, &(nodes, leader)) in seen.iter().enumerate().skip(3) {
        assert_eq!((nodes, leader), (3, 1), "request {i} not under the new leader");
    }

    let stats = server.shutdown();
    assert_eq!(stats.requests, n_requests);
    assert_eq!(stats.failed_on_leader_loss, 0, "lockstep has nothing in flight to fail");
    let m = stats.adaptation.expect("elastic path reports adaptation");
    assert_eq!(m.failovers, 1, "{m}");
    assert_eq!(m.leader_handoffs, 1, "leader loss must count a handoff: {m}");
    assert!(
        m.speculative_hits >= 1,
        "leader failover was not served from the speculative cache: {m}"
    );
    assert_eq!(m.inline_replans, 0, "{m}");
}

#[test]
fn pipelined_leader_loss_replays_in_flight_and_readmits_the_rest() {
    // The pipelined acceptance property for leader death: the generation
    // aborts, requests caught in flight are captured and *replayed* on the
    // rebuilt pipeline (bit-identical, in submission order — never failed
    // back while budget remains), queued requests re-admit under the
    // elected leader, later responses ride the surviving 3-node cluster
    // bit-exactly, and the failover plan comes from the speculative cache.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let plan4 = plan_for_testbed(&model, &base);
    let c4 = engine::evaluate(&model, &plan4, &base).total;
    let trace = ConditionTrace::stable(4).with_outage(0, 2.5 * c4, f64::INFINITY);

    let cfg = ServeConfig {
        max_batch: 1,
        batch_window: Duration::ZERO,
        queue_depth: 32,
        pipeline_depth: 4,
        ..ServeConfig::default()
    };
    let server = Server::start_elastic(
        model.clone(),
        WeightStore::for_model(&model, 5),
        base.clone(),
        trace,
        cfg,
        ElasticConfig::default(),
    );
    let ws = WeightStore::for_model(&model, 5);
    let n_requests = 10u64;
    let inputs: Vec<Tensor> = (0..n_requests)
        .map(|i| Tensor::random(16, 16, 3, 8000 + i))
        .collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|t| server.submit(t.clone()).expect("admission failed"))
        .collect();

    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut last_seq: Option<u64> = None;
    for (i, (input, rx)) in inputs.iter().zip(rxs).enumerate() {
        match rx.recv() {
            Ok(resp) => {
                ok += 1;
                let reference = run_reference(&model, &ws, input);
                assert_eq!(
                    reference.max_abs_diff(&resp.output),
                    0.0,
                    "request {i} output diverged"
                );
                if let Some(prev) = last_seq {
                    assert!(resp.seq > prev, "request {i} delivered out of order");
                }
                last_seq = Some(resp.seq);
                if resp.nodes == 3 {
                    assert_eq!(resp.leader, 1, "3-node generation must run under rank 1");
                } else {
                    assert_eq!((resp.nodes, resp.leader), (4, 0));
                }
                // the boundary at vt = 3c4 aborts the old generation, so
                // every request from index 3 on re-admits under the new
                // leader deterministically
                if i >= 3 {
                    assert_eq!((resp.nodes, resp.leader), (3, 1), "request {i}");
                }
            }
            Err(_) => failed += 1,
        }
    }
    assert_eq!(ok, n_requests, "replay must complete every in-flight request");
    assert_eq!(failed, 0, "no request may fail back while replay budget remains");

    let stats = server.shutdown();
    assert_eq!(stats.requests, n_requests);
    assert_eq!(stats.failed_on_shutdown, 0);
    assert_eq!(stats.failed_on_leader_loss, 0);
    assert!(
        stats.replay_attempts >= stats.replayed_on_leader_loss,
        "every replayed request costs at least one attempt"
    );
    let p = stats.pipeline.expect("pipelined path reports stage stats");
    assert!(p.generations >= 2, "leader loss must rebuild the pipeline: {p}");
    assert_eq!(p.items, ok, "delivered items must match client-side oks");
    let m = stats.adaptation.expect("elastic path reports adaptation");
    assert_eq!(m.failovers, 1, "{m}");
    assert_eq!(m.leader_handoffs, 1, "{m}");
    assert!(
        m.speculative_hits >= 1,
        "leader failover was not served from the speculative cache: {m}"
    );
    assert_eq!(m.inline_replans, 0, "{m}");
}

#[test]
fn controller_replans_match_direct_planning() {
    // the plan the controller swaps to on failover must equal planning
    // directly for the degraded testbed (no hidden state)
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let trace = ConditionTrace::stable(4).with_outage(1, 1.0, f64::INFINITY);
    let mut ctl = ElasticController::new(
        model.clone(),
        base.clone(),
        trace,
        ElasticConfig::default(),
    );
    let healthy = ctl.on_batch(0.0);
    assert_eq!(*healthy.plan, plan_for_testbed(&model, &base));
    let degraded = ctl.on_batch(2.0);
    let tb3 = base.subset(&[true, false, true, true]);
    assert_eq!(degraded.testbed, tb3);
    assert_eq!(*degraded.plan, plan_for_testbed(&model, &tb3));
}

#[test]
fn batch_boundaries_never_block_on_replanning() {
    // A mid-stream bandwidth collapse forces a replan; with the background
    // replanner, that search must run off the router thread — no batch
    // boundary executes DPP inline, and acquisition stays at pointer-load
    // latency even across the swap.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let plan0 = plan_for_testbed(&model, &base);
    let c0 = engine::evaluate(&model, &plan0, &base).total;
    let trace = ConditionTrace::stable(4).with_bandwidth_dip(2.5 * c0, f64::INFINITY, 0.1);
    let server = Server::start_elastic(
        model.clone(),
        WeightStore::for_model(&model, 5),
        base,
        trace,
        per_request_batches(),
        ElasticConfig::default(),
    );
    let ws = WeightStore::for_model(&model, 5);
    for i in 0..8u64 {
        let input = Tensor::random(16, 16, 3, 3000 + i);
        let reference = run_reference(&model, &ws, &input);
        let resp = server.infer(input).unwrap();
        assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "request {i}");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 8);
    let m = stats.adaptation.expect("elastic path reports adaptation");
    assert_eq!(m.checks, 8);
    assert_eq!(m.inline_replans, 0, "a batch boundary ran a DPP search inline: {m}");
    assert!(m.degraded_checks >= 1, "collapse never reached the background monitor: {m}");
    assert!(m.replans >= 2, "background planner never replanned: {m}");
    let stall = stats.boundary_stall.expect("elastic path reports boundary stalls");
    assert_eq!(stall.count, 8, "one stall sample per boundary");
    // Steady-state acquisition is a trace sample plus one atomic epoch
    // load; even a noisy CI box keeps the median far below search time.
    assert!(
        stall.p50 < Duration::from_millis(20),
        "batch boundaries are stalling on planning: {stall}"
    );
}

#[test]
fn node_loss_failover_is_served_from_speculative_cache() {
    // While the cluster is healthy the background planner pre-computes the
    // best n−1 plan per likely-lost node, so a real node loss is answered
    // from the cache — the failover rendezvous never waits on a search, and
    // the served plan equals planning directly for the degraded cluster.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let trace = ConditionTrace::stable(4).with_outage(2, 1.0, f64::INFINITY);
    let mut fe = ElasticFrontend::start(
        model.clone(),
        base.clone(),
        trace,
        ElasticConfig::default(),
    );
    let healthy = fe.acquire(0.5);
    assert_eq!(healthy.nodes, 4);
    let degraded = fe.acquire(1.5);
    assert_eq!(degraded.nodes, 3);
    assert_eq!(degraded.alive, vec![true, true, false, true]);
    let tb3 = base.subset(&[true, true, false, true]);
    assert_eq!(
        *degraded.plan,
        plan_for_testbed(&model, &tb3),
        "failover plan must equal direct planning for the surviving cluster"
    );
    let (m, stalls) = fe.finish();
    assert_eq!(m.checks, 2);
    assert_eq!(m.failovers, 1);
    assert!(
        m.speculative_plans >= 3,
        "healthy-cluster speculation did not cover the n−1 cells: {m}"
    );
    assert_eq!(
        m.speculative_hits, 1,
        "node loss was not served from the speculative cache: {m}"
    );
    assert_eq!(m.inline_replans, 0, "{m}");
    assert_eq!(stalls.count, 2);
}

#[test]
fn pipelined_serving_survives_failover_with_drain_and_flush() {
    // The pipelined acceptance property: under pipeline_depth > 1 a plan
    // swap becomes a drain-and-flush (in-flight inferences complete under
    // the old plan, the pipeline rebuilds on the new plan/node set), the
    // frontend is consulted once per drained generation rather than per
    // batch, and no request is lost or corrupted across the swap.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let plan4 = plan_for_testbed(&model, &base);
    let c4 = engine::evaluate(&model, &plan4, &base).total;
    let tb3 = base.subset(&[true, true, false, true]);
    let plan3 = plan_for_testbed(&model, &tb3);
    let c3 = engine::evaluate(&model, &plan3, &tb3).total;

    // node 2 dies during the fourth batch's window, rejoins ~3 batches later
    let down_at = 2.5 * c4;
    let up_at = 3.0 * c4 + 2.5 * c3;
    let trace = ConditionTrace::stable(4).with_outage(2, down_at, up_at);

    let cfg = ServeConfig {
        max_batch: 1,
        batch_window: Duration::ZERO,
        queue_depth: 32,
        pipeline_depth: 4,
        ..ServeConfig::default()
    };
    let server = Server::start_elastic(
        model.clone(),
        WeightStore::for_model(&model, 5),
        base,
        trace,
        cfg,
        ElasticConfig::default(),
    );

    // submit the whole stream up front so batches genuinely overlap inside
    // the pipeline; responses come back in submission order per channel
    let ws = WeightStore::for_model(&model, 5);
    let n_requests = 10u64;
    let inputs: Vec<Tensor> = (0..n_requests)
        .map(|i| Tensor::random(16, 16, 3, 5000 + i))
        .collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|t| server.submit(t.clone()).expect("admission failed"))
        .collect();
    let mut nodes_seen = Vec::new();
    for (i, (input, rx)) in inputs.iter().zip(rxs).enumerate() {
        let resp = rx.recv().expect("request lost across drain-and-flush");
        let reference = run_reference(&model, &ws, input);
        assert_eq!(
            reference.max_abs_diff(&resp.output),
            0.0,
            "request {i} output diverged"
        );
        nodes_seen.push(resp.nodes);
    }
    assert_eq!(nodes_seen.len(), n_requests as usize, "lost requests");
    // batches 0..=2 run healthy (vt = 0, c4, 2c4 < down_at); batch 3 sees
    // the outage at its generation probe and serves on 3 nodes
    assert_eq!(&nodes_seen[..3], &[4, 4, 4], "pre-failure generations degraded early");
    assert_eq!(nodes_seen[3], 3, "failover missed its drain boundary");
    assert!(
        nodes_seen[3..].contains(&4),
        "node rejoin never observed: {nodes_seen:?}"
    );

    let stats = server.shutdown();
    assert_eq!(stats.requests, n_requests);
    assert_eq!(stats.failed_on_shutdown, 0);
    let p = stats.pipeline.expect("pipelined path reports stage stats");
    assert!(
        p.generations >= 3,
        "down + up swaps must each flush a generation: {p}"
    );
    assert_eq!(p.items, n_requests);
    let m = stats.adaptation.expect("elastic path reports adaptation metrics");
    assert_eq!(
        m.checks, p.generations,
        "pipelined mode consults the frontend once per generation: {m}"
    );
    assert!(m.checks < n_requests, "frontend consulted per batch, not per generation");
    assert!(m.failovers >= 2, "expected down + up failovers: {m}");
    assert_eq!(m.inline_replans, 0, "{m}");
}

#[test]
fn lossy_link_serving_stays_correct() {
    // bursty 15%-bandwidth windows: adaptation may replan repeatedly, but
    // every response stays bit-exact and accounted for
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let server = Server::start_elastic(
        model.clone(),
        WeightStore::for_model(&model, 9),
        base,
        ConditionTrace::lossy_link(4, 11),
        per_request_batches(),
        ElasticConfig::default(),
    );
    let ws = WeightStore::for_model(&model, 9);
    for i in 0..8u64 {
        let input = Tensor::random(16, 16, 3, 2000 + i);
        let reference = run_reference(&model, &ws, &input);
        let resp = server.infer(input).unwrap();
        assert_eq!(reference.max_abs_diff(&resp.output), 0.0);
        assert_eq!(resp.nodes, 4, "lossy link must not drop nodes");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 8);
    let m = stats.adaptation.unwrap();
    assert_eq!(m.checks, 8);
    assert_eq!(m.failovers, 0);
}
