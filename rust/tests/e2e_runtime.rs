//! End-to-end three-layer tests: the Rust coordinator loads the AOT-compiled
//! JAX/Pallas artifacts via PJRT and the numerics must agree with the native
//! Rust kernels (which the distributed engine is validated against).
//!
//! Requires `make artifacts` (the Makefile's `test` target runs it first).
//! Tests skip with a loud message when artifacts are absent so plain
//! `cargo test` still passes in a fresh checkout.

use flexpie::compute::{
    compute_region, run_reference, PatchStore, RegionTensor, Tensor, WeightStore,
};
use flexpie::model::zoo;
use flexpie::partition::Region;
use flexpie::runtime::{signature, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime load"))
}

#[test]
fn runtime_loads_manifest_and_platform() {
    let Some(rt) = runtime() else { return };
    assert!(rt.n_artifacts() >= 9, "expected the EdgeNet menu");
    let platform = rt.platform().to_lowercase();
    assert!(platform == "cpu" || platform == "host", "platform = {platform}");
    // every EdgeNet(16) layer must be covered
    for l in &zoo::edgenet(16).layers {
        let sig = signature(l, l.in_h, l.in_w);
        assert!(rt.has(&sig), "missing artifact {sig}");
    }
}

#[test]
fn pjrt_layers_match_native_kernels() {
    let Some(rt) = runtime() else { return };
    let model = zoo::edgenet(16);
    let ws = WeightStore::for_model(&model, 77);
    let mut cur = Tensor::random(16, 16, 3, 123);
    for (i, layer) in model.layers.iter().enumerate() {
        // native path
        let mut store = PatchStore::new();
        store.add(RegionTensor::new(
            Region::full(layer.in_h, layer.in_w, layer.in_c),
            cur.clone(),
        ));
        let native = compute_region(
            layer,
            &ws.layers[i],
            &store,
            &Region::full(layer.out_h, layer.out_w, layer.out_c),
        )
        .t;
        // PJRT path (AOT-lowered Pallas kernel)
        let pjrt = rt.execute_layer(layer, &ws.layers[i], &cur).expect("pjrt exec");
        assert_eq!((pjrt.h, pjrt.w, pjrt.c), (native.h, native.w, native.c));
        let diff = native.max_abs_diff(&pjrt);
        assert!(
            diff < 1e-4,
            "layer {i} ({}): native vs PJRT diff {diff}",
            layer.name
        );
        cur = native; // feed the native activations forward
    }
}

#[test]
fn pjrt_full_chain_matches_reference() {
    let Some(rt) = runtime() else { return };
    let model = zoo::edgenet(16);
    let ws = WeightStore::for_model(&model, 5);
    let input = Tensor::random(16, 16, 3, 9);
    let reference = run_reference(&model, &ws, &input);

    let mut cur = input;
    for (i, layer) in model.layers.iter().enumerate() {
        cur = rt.execute_layer(layer, &ws.layers[i], &cur).expect("pjrt exec");
    }
    assert_eq!((cur.h, cur.w, cur.c), (1, 1, 10));
    let diff = reference.max_abs_diff(&cur);
    assert!(diff < 1e-3, "full-chain PJRT vs reference diff {diff}");
}

#[test]
fn pjrt_executable_cache_is_reused() {
    let Some(rt) = runtime() else { return };
    let model = zoo::edgenet(16);
    let ws = WeightStore::for_model(&model, 1);
    let layer = &model.layers[0];
    let input = Tensor::random(16, 16, 3, 2);
    // first call compiles; subsequent calls must be much faster and equal
    let out1 = rt.execute_layer(layer, &ws.layers[0], &input).unwrap();
    let t0 = std::time::Instant::now();
    let out2 = rt.execute_layer(layer, &ws.layers[0], &input).unwrap();
    let cached = t0.elapsed();
    assert_eq!(out1.data, out2.data);
    assert!(cached.as_millis() < 200, "cached exec too slow: {cached:?}");
}

#[test]
fn missing_signature_is_a_clean_error() {
    let Some(rt) = runtime() else { return };
    let odd = flexpie::model::LayerMeta::conv(
        "odd",
        flexpie::model::ConvType::Standard,
        17,
        17,
        3,
        5,
        3,
        1,
        1,
    );
    let ws = flexpie::compute::LayerWeights {
        w: vec![0.0; (3 * 3 * 3 * 5) as usize],
        b: vec![0.0; 5],
    };
    let input = Tensor::zeros(17, 17, 3);
    let err = rt.execute_layer(&odd, &ws, &input).unwrap_err();
    assert!(err.to_string().contains("no artifact"), "{err}");
}
