//! Robustness and failure-injection tests: heterogeneous clusters, corrupt
//! artifacts, degenerate models, protocol failures, and fuzzed persistence.

use flexpie::compute::{Tensor, WeightStore};
use flexpie::cost::query::compute_query_tiles;
use flexpie::cost::CostSource;
use flexpie::model::passes::{preoptimize, raw_conv_bn_relu_chain, verify_planner_ready};
use flexpie::model::{zoo, ConvType, LayerMeta, Model};
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::geometry::out_tiles;
use flexpie::partition::{Plan, Scheme};
use flexpie::planner::Dpp;
use flexpie::util::json::{parse, Json};
use flexpie::util::prop::check;
use flexpie::util::rng::Rng;
use flexpie::util::tmp::TempDir;

// ---------------------------------------------------------------------------
// heterogeneous clusters
// ---------------------------------------------------------------------------

#[test]
fn heterogeneous_speeds_raise_cost_and_shift_bottleneck() {
    let model = zoo::mobilenet_v1(224, 1000).truncated(9);
    let homo = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let hetero = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0))
        .with_speed(vec![1.0, 1.0, 0.5, 1.0]);
    let plan_h = Dpp::new(&model, &CostSource::analytic(&homo)).plan();
    let plan_x = Dpp::new(&model, &CostSource::analytic(&hetero)).plan();
    // a half-speed node can only make things slower...
    assert!(plan_x.est_cost > plan_h.est_cost);
    // ...but the planner must still produce something executable with exact
    // numerics on the heterogeneous cluster
    let diff = flexpie::engine::verify_plan(&model, &plan_x, &hetero, 3);
    assert_eq!(diff, 0.0);
}

#[test]
fn heterogeneous_compute_query_respects_speed() {
    let layer = LayerMeta::conv("c", ConvType::Standard, 16, 16, 8, 8, 3, 1, 1);
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0))
        .with_speed(vec![2.0, 1.0, 1.0, 1.0]);
    let tiles = out_tiles(&layer, Scheme::InH, 4);
    let q = compute_query_tiles(&layer, &tiles, Scheme::InH, &tb);
    // node 0 is twice as fast → half the effective flops
    assert!((q.per_node_flops[0] * 2.0 - q.per_node_flops[1]).abs() < 1e-6);
}

#[test]
#[should_panic(expected = "edge clusters are small")]
fn oversized_cluster_rejected() {
    let _ = Testbed::new(64, Topology::Ring, Bandwidth::gbps(1.0));
}

// ---------------------------------------------------------------------------
// protocol / engine failure injection
// ---------------------------------------------------------------------------

#[test]
#[should_panic(expected = "invalid plan")]
fn engine_rejects_invalid_plan() {
    let model = zoo::edgenet(16);
    let mut plan = Plan::uniform(Scheme::InH, model.n_layers());
    plan.steps.last_mut().unwrap().mode = flexpie::partition::Mode::NT; // illegal
    let ws = WeightStore::for_model(&model, 1);
    let input = Tensor::random(16, 16, 3, 1);
    let _ = flexpie::cluster::run_distributed(&model, &plan, &ws, &input, 4);
}

#[test]
#[should_panic]
fn engine_rejects_wrong_plan_length() {
    let model = zoo::edgenet(16);
    let plan = Plan::uniform(Scheme::InH, model.n_layers() - 1);
    let ws = WeightStore::for_model(&model, 1);
    let input = Tensor::random(16, 16, 3, 1);
    let _ = flexpie::cluster::run_distributed(&model, &plan, &ws, &input, 4);
}

#[test]
#[should_panic(expected = "input shape mismatch")]
fn reference_rejects_wrong_input_shape() {
    let model = zoo::edgenet(16);
    let ws = WeightStore::for_model(&model, 1);
    let bad = Tensor::random(8, 8, 3, 1);
    let _ = flexpie::compute::run_reference(&model, &ws, &bad);
}

// ---------------------------------------------------------------------------
// artifact / persistence corruption
// ---------------------------------------------------------------------------

#[test]
fn corrupt_manifest_is_clean_error() {
    let dir = TempDir::new("corrupt");
    std::fs::write(dir.path().join("manifest.json"), "{not json").unwrap();
    assert!(flexpie::runtime::Runtime::load(dir.path()).is_err());
    std::fs::write(dir.path().join("manifest.json"), r#"{"wrong_key": {}}"#).unwrap();
    match flexpie::runtime::Runtime::load(dir.path()) {
        Ok(_) => panic!("corrupt manifest accepted"),
        Err(err) => assert!(err.to_string().contains("artifacts"), "{err}"),
    }
}

#[test]
fn manifest_pointing_at_missing_file_errors_at_use() {
    let dir = TempDir::new("missing_hlo");
    std::fs::write(
        dir.path().join("manifest.json"),
        r#"{"artifacts": {"conv2d_ih4_iw4_ic1_oc1_k1_s1_p0": "nope.hlo.txt"}}"#,
    )
    .unwrap();
    let rt = flexpie::runtime::Runtime::load(dir.path()).unwrap();
    let layer = LayerMeta::conv("c", ConvType::Pointwise, 4, 4, 1, 1, 1, 1, 0);
    let ws = flexpie::compute::LayerWeights { w: vec![1.0], b: vec![0.0] };
    let input = Tensor::zeros(4, 4, 1);
    assert!(rt.execute_layer(&layer, &ws, &input).is_err());
}

#[test]
fn corrupt_gbdt_file_is_clean_error() {
    let dir = TempDir::new("gbdt_corrupt");
    let p = dir.path().join("m.json");
    std::fs::write(&p, r#"{"base": 1.0}"#).unwrap();
    assert!(flexpie::cost::gbdt::Gbdt::load(&p).is_err());
    std::fs::write(&p, "garbage").unwrap();
    assert!(flexpie::cost::gbdt::Gbdt::load(&p).is_err());
}

#[test]
fn prop_json_fuzz_roundtrip() {
    // random JSON values survive serialize → parse exactly
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.f64() - 0.5) * 10f64.powi(rng.below(40) as i32 - 20)),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.pick(&['a', '"', '\\', 'é', '\n', '7'])).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::obj(
                (0..rng.below(4))
                    .map(|i| (["k0", "k1", "k2", "k3"][i], random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json_fuzz_roundtrip", 300, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = parse(&text).map_err(|e| format!("{e}: {text}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// pre-optimization passes → planner integration
// ---------------------------------------------------------------------------

#[test]
fn raw_imported_graph_plans_and_executes() {
    let raw = raw_conv_bn_relu_chain("imported", 4, 16, 8);
    let (model, stats) = preoptimize(&raw);
    assert_eq!(stats.bn_folded, 4);
    assert_eq!(stats.activations_fused, 4);
    verify_planner_ready(&model).unwrap();
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let plan = Dpp::new(&model, &CostSource::analytic(&tb)).plan();
    // fused ReLUs must survive distributed execution (max(0,·) per node)
    assert_eq!(flexpie::engine::verify_plan(&model, &plan, &tb, 5), 0.0);
}

// ---------------------------------------------------------------------------
// degenerate models
// ---------------------------------------------------------------------------

#[test]
fn fc_only_model_plans_on_any_cluster() {
    let model = Model::new(
        "fc_only",
        vec![LayerMeta::dense("fc1", 1, 64, 64), LayerMeta::dense("fc2", 1, 64, 10)],
    );
    for nodes in [2usize, 4, 6] {
        let tb = Testbed::new(nodes, Topology::Ps, Bandwidth::gbps(1.0));
        let plan = Dpp::new(&model, &CostSource::analytic(&tb)).plan();
        plan.validate().unwrap();
        // single-row FCs cannot be spatially split — execution must still be
        // exact (idle nodes simply hold nothing)
        assert_eq!(flexpie::engine::verify_plan(&model, &plan, &tb, 2), 0.0);
    }
}

#[test]
fn stride_heavy_model_executes() {
    // consecutive stride-2 layers shrink the map below the node count
    let model = Model::new(
        "shrinky",
        vec![
            LayerMeta::conv("a", ConvType::Standard, 16, 16, 3, 8, 3, 2, 1),
            LayerMeta::conv("b", ConvType::Standard, 8, 8, 8, 8, 3, 2, 1),
            LayerMeta::conv("c", ConvType::Standard, 4, 4, 8, 8, 3, 2, 1),
            LayerMeta::conv("d", ConvType::Standard, 2, 2, 8, 8, 3, 2, 1),
        ],
    );
    for nodes in [3usize, 4, 6] {
        let tb = Testbed::new(nodes, Topology::Mesh, Bandwidth::gbps(0.5));
        let plan = Dpp::new(&model, &CostSource::analytic(&tb)).plan();
        assert_eq!(flexpie::engine::verify_plan(&model, &plan, &tb, 8), 0.0, "n={nodes}");
    }
}

#[test]
fn big_kernel_model_executes() {
    let model = Model::new(
        "wide_rf",
        vec![
            LayerMeta::conv("a", ConvType::Standard, 20, 20, 3, 4, 7, 1, 3),
            LayerMeta::conv("b", ConvType::Standard, 20, 20, 4, 4, 5, 1, 2),
        ],
    );
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(0.2));
    let plan = Dpp::new(&model, &CostSource::analytic(&tb)).plan();
    assert_eq!(flexpie::engine::verify_plan(&model, &plan, &tb, 4), 0.0);
}
