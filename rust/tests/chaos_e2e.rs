//! Chaos end-to-end: the acceptance test for leader failover and the
//! deterministic fault-injection harness.
//!
//! Three invariants must survive every seeded fault schedule — kills and
//! restores of any node *including the leader*, back-to-back failures, and
//! bandwidth collapses, all injected at batch boundaries:
//!
//! 1. surviving outputs stay **bit-identical** to the fresh single-node
//!    reference,
//! 2. no accepted request is **silently dropped** (every one completes or
//!    is explicitly failed and accounted by the router),
//! 3. **completion order is preserved** (router delivery sequence numbers
//!    increase in submission order).
//!
//! The three fixed CI seeds run in `generated_chaos_three_seeds_pipelined`,
//! which prints a single-line `RESULT {...}` JSON summary (events injected,
//! failovers, requests lost — must be 0) that CI uploads as an artifact.

use std::time::Duration;

use flexpie::elastic::{run_chaos, ChaosEvent, ChaosOutcome, ChaosSchedule, ElasticConfig};
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::plan_for_testbed;
use flexpie::serve::ServeConfig;
use flexpie::util::bench::emit_result;
use flexpie::util::json::Json;

/// The fixed seeds CI runs as a required job.
const CI_SEEDS: [u64; 3] = [11, 23, 47];

fn chaos_cfg(depth: usize) -> ServeConfig {
    ServeConfig {
        max_batch: 1,
        batch_window: Duration::ZERO,
        queue_depth: 64,
        pipeline_depth: depth,
        ..ServeConfig::default()
    }
}

/// Per-item virtual cost of the healthy 4-node plan — the unit chaos slot
/// lengths are expressed in, so events land a known number of batches in.
fn healthy_cost(model: &flexpie::model::Model, base: &Testbed) -> f64 {
    let plan = plan_for_testbed(model, base);
    engine::evaluate(model, &plan, base).total
}

#[test]
fn generated_chaos_three_seeds_pipelined() {
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let c4 = healthy_cost(&model, &base);
    let requests = 20u64;
    let mut results: Vec<ChaosOutcome> = Vec::new();
    for &seed in &CI_SEEDS {
        let schedule = ChaosSchedule::generate(4, seed, 8, 2.0 * c4);
        assert!(
            schedule.kills_leader(),
            "seed {seed}: schedule never strikes the leader"
        );
        let out = run_chaos(
            &model,
            &base,
            &schedule,
            chaos_cfg(3),
            ElasticConfig::default(),
            requests,
            10_000 * (seed + 1),
        );
        out.verify().unwrap_or_else(|e| panic!("seed {seed}: {e} ({out})"));
        assert!(out.failovers >= 1, "seed {seed}: no failover observed: {out}");
        results.push(out);
    }
    let sum = |f: fn(&ChaosOutcome) -> u64| results.iter().map(f).sum::<u64>();
    emit_result(vec![
        ("seeds", Json::arr(CI_SEEDS.iter().map(|&s| Json::Num(s as f64)))),
        ("requests", Json::Num(sum(|o| o.requests) as f64)),
        ("events_injected", Json::Num(sum(|o| o.events as u64) as f64)),
        ("failovers", Json::Num(sum(|o| o.failovers) as f64)),
        ("leader_handoffs", Json::Num(sum(|o| o.leader_handoffs) as f64)),
        ("speculative_hits", Json::Num(sum(|o| o.speculative_hits) as f64)),
        ("ok", Json::Num(sum(|o| o.ok) as f64)),
        ("failed_reported", Json::Num(sum(|o| o.failed_reported) as f64)),
        ("requests_lost", Json::Num(sum(|o| o.lost) as f64)),
        ("mismatches", Json::Num(sum(|o| o.mismatches) as f64)),
        ("reordered", Json::Num(sum(|o| o.reordered) as f64)),
        ("replays", Json::Num(sum(|o| o.replays) as f64)),
        ("replay_attempts", Json::Num(sum(|o| o.replay_attempts) as f64)),
    ]);
}

#[test]
fn leader_killed_mid_stream_recovers_with_zero_lost() {
    // The headline scripted case: the leader dies permanently mid-stream
    // under pipelining. Zero silent drops, surviving outputs bit-identical
    // (audited inside run_chaos), and the failover served speculatively.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let c4 = healthy_cost(&model, &base);
    let schedule = ChaosSchedule {
        nodes: 4,
        seed: 0,
        slot: c4,
        events: vec![ChaosEvent::Kill { node: 0, from: 2.5 * c4, until: f64::INFINITY }],
    };
    assert!(schedule.kills_leader());
    let out = run_chaos(
        &model,
        &base,
        &schedule,
        chaos_cfg(4),
        ElasticConfig::default(),
        12,
        4_400,
    );
    out.verify().unwrap_or_else(|e| panic!("{e} ({out})"));
    assert_eq!(out.failovers, 1, "{out}");
    assert_eq!(out.leader_handoffs, 1, "{out}");
    assert!(
        out.speculative_hits >= 1,
        "leader failover was not a speculative cache hit: {out}"
    );
    assert_eq!(out.min_nodes, 3, "post-failover traffic must ride 3 nodes: {out}");
    // with replay recovery, requests caught in flight by the abort are
    // re-executed on the rebuilt pipeline instead of failing back to the
    // client: every request completes, none are reported failed
    assert_eq!(out.ok, 12, "replay must leave no request behind: {out}");
    assert_eq!(out.failed_reported, 0, "{out}");
    assert!(out.replay_attempts >= out.replays, "{out}");
    assert!(out.generations >= 2, "leader loss must rebuild the pipeline: {out}");
}

#[test]
fn leader_kill_with_zero_replay_budget_degrades_to_explicit_failure() {
    // replay_budget = 0 restores the pre-replay contract: requests caught
    // in flight by the abort are failed back explicitly (never silently),
    // and the accounting invariant ok + failed_reported == requests holds.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let c4 = healthy_cost(&model, &base);
    let schedule = ChaosSchedule {
        nodes: 4,
        seed: 0,
        slot: c4,
        events: vec![ChaosEvent::Kill { node: 0, from: 2.5 * c4, until: f64::INFINITY }],
    };
    let cfg = ServeConfig { replay_budget: 0, ..chaos_cfg(4) };
    let out = run_chaos(
        &model,
        &base,
        &schedule,
        cfg,
        ElasticConfig::default(),
        12,
        4_400,
    );
    out.verify().unwrap_or_else(|e| panic!("{e} ({out})"));
    assert_eq!(out.replays, 0, "budget 0 must never replay: {out}");
    assert_eq!(out.replay_attempts, 0, "{out}");
    assert_eq!(out.ok + out.failed_reported, 12, "{out}");
    // requests 3..11 deterministically re-admit under the new leader, so at
    // least those 9 complete; in-flight requests at the abort are failed
    assert!(out.ok >= 9, "{out}");
}

#[test]
fn back_to_back_leader_and_worker_kill_then_restore() {
    // Node 0 and node 2 die within the same inter-boundary window — one
    // boundary observes both at once, drops to 2 nodes under rank 1, and
    // the cluster recovers fully when they rejoin. Lockstep mode: every
    // request must complete.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let c4 = healthy_cost(&model, &base);
    let schedule = ChaosSchedule {
        nodes: 4,
        seed: 0,
        slot: c4,
        events: vec![
            ChaosEvent::Kill { node: 0, from: 2.5 * c4, until: 5.5 * c4 },
            ChaosEvent::Kill { node: 2, from: 2.6 * c4, until: 5.6 * c4 },
        ],
    };
    let out = run_chaos(
        &model,
        &base,
        &schedule,
        chaos_cfg(1), // lockstep
        ElasticConfig::default(),
        14,
        5_500,
    );
    out.verify().unwrap_or_else(|e| panic!("{e} ({out})"));
    assert_eq!(out.ok, 14, "lockstep leaves nothing in flight to fail: {out}");
    assert_eq!(out.min_nodes, 2, "double failure never observed: {out}");
    assert_eq!(out.max_nodes, 4, "recovery never observed: {out}");
    assert!(out.failovers >= 2, "down + up failovers expected: {out}");
    assert!(out.leader_handoffs >= 2, "handoff + reclaim expected: {out}");
}

#[test]
fn bandwidth_collapse_during_leader_outage_stays_exact() {
    // Compound fault: the link collapses while the leader is down. Plans
    // may swap repeatedly; numerics must not move and nothing may be lost.
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let c4 = healthy_cost(&model, &base);
    let schedule = ChaosSchedule {
        nodes: 4,
        seed: 0,
        slot: c4,
        events: vec![
            ChaosEvent::Kill { node: 0, from: 1.5 * c4, until: 9.5 * c4 },
            ChaosEvent::Collapse { factor: 0.1, from: 2.5 * c4, until: 6.5 * c4 },
        ],
    };
    let out = run_chaos(
        &model,
        &base,
        &schedule,
        chaos_cfg(2),
        ElasticConfig::default(),
        12,
        6_600,
    );
    out.verify().unwrap_or_else(|e| panic!("{e} ({out})"));
    assert!(out.failovers >= 1, "{out}");
    assert!(out.leader_handoffs >= 1, "{out}");
    assert_eq!(out.min_nodes, 3, "{out}");
}
