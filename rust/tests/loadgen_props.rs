//! Property tests for the load-generation spine: histogram merge
//! correctness (the thing that makes multi-process percentiles trustworthy)
//! and arrival-schedule determinism (the thing that makes the A-suites
//! CI-gateable).
//!
//! Replay a failure with `FLEXPIE_PROP_SEED=<seed> cargo test --test loadgen_props`.

use flexpie::loadgen::hist::{bucket_width, Histogram};
use flexpie::loadgen::{ArrivalProcess, ScheduleSpec};
use flexpie::util::prop::check;
use flexpie::util::rng::Rng;
use flexpie::{prop_assert, prop_assert_eq};

/// Latency-like values spanning the linear buckets (< 32 ns) through
/// multi-second outliers — every octave the histogram owns.
fn random_samples(rng: &mut Rng, n: usize) -> Vec<u64> {
    (0..n)
        .map(|_| {
            let magnitude = 10u64.pow(rng.range_incl(0, 10) as u32);
            rng.next_u64() % magnitude.max(1)
        })
        .collect()
}

fn record_all(samples: &[u64]) -> Histogram {
    let mut h = Histogram::new();
    for &v in samples {
        h.record(v);
    }
    h
}

/// The harness's percentile convention over raw samples: rank
/// `ceil(q·n)` clamped to `[1, n]`, 1-indexed into the sorted list.
fn exact_percentile(sorted: &[u64], q: f64) -> u64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

#[test]
fn prop_merge_is_commutative_and_exact() {
    check("hist_merge_commutative", 200, |rng| {
        let a = random_samples(rng, rng.range_incl(0, 400));
        let b = random_samples(rng, rng.range_incl(1, 400));
        let (ha, hb) = (record_all(&a), record_all(&b));

        let mut ab = ha.clone();
        ab.merge(&hb);
        let mut ba = hb.clone();
        ba.merge(&ha);

        prop_assert_eq!(ab.count(), ba.count());
        prop_assert_eq!(ab.min(), ba.min());
        prop_assert_eq!(ab.max(), ba.max());
        prop_assert_eq!(ab.to_json().to_string(), ba.to_json().to_string());
        for q in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            prop_assert!(
                ab.percentile(q) == ba.percentile(q),
                "q={q}: {} vs {}",
                ab.percentile(q),
                ba.percentile(q)
            );
        }

        // merging is also exactly "recording everything in one place"
        let mut both = a.clone();
        both.extend_from_slice(&b);
        let single = record_all(&both);
        prop_assert_eq!(ab.to_json().to_string(), single.to_json().to_string());
        Ok(())
    });
}

#[test]
fn prop_merged_percentiles_within_one_bucket_of_raw() {
    check("hist_percentile_error_bound", 200, |rng| {
        let a = random_samples(rng, rng.range_incl(1, 300));
        let b = random_samples(rng, rng.range_incl(1, 300));
        let mut h = record_all(&a);
        h.merge(&record_all(&b));

        let mut all = a.clone();
        all.extend_from_slice(&b);
        all.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let exact = exact_percentile(&all, q);
            let got = h.percentile(q);
            // the histogram answers with the ceiling of the bucket holding
            // the rank-q sample (clamped to the tracked max), so it can
            // only overshoot, and never by more than that bucket's width
            prop_assert!(
                got >= exact && got - exact <= bucket_width(exact),
                "q={q}: got {got}, exact {exact}, width {}",
                bucket_width(exact)
            );
        }
        Ok(())
    });
}

#[test]
fn prop_count_conservation_across_agent_merges() {
    check("hist_count_conservation", 150, |rng| {
        // one sample population, sharded across 1..=6 "agents" — the merged
        // histogram must conserve every recorded sample and every moment
        // the shards tracked
        let samples = random_samples(rng, rng.range_incl(1, 600));
        let agents = rng.range_incl(1, 6);
        let mut shards = vec![Histogram::new(); agents];
        for (i, &v) in samples.iter().enumerate() {
            shards[i % agents].record(v);
        }
        prop_assert_eq!(
            shards.iter().map(Histogram::count).sum::<u64>(),
            samples.len() as u64
        );
        let mut merged = Histogram::new();
        for s in &shards {
            merged.merge(s);
        }
        let single = record_all(&samples);
        prop_assert_eq!(merged.count(), samples.len() as u64);
        prop_assert_eq!(merged.min(), single.min());
        prop_assert_eq!(merged.max(), single.max());
        prop_assert_eq!(merged.to_json().to_string(), single.to_json().to_string());

        // and the JSON round trip an agent report rides preserves it all
        let back = Histogram::from_json(&merged.to_json()).unwrap();
        prop_assert_eq!(back.to_json().to_string(), merged.to_json().to_string());
        Ok(())
    });
}

#[test]
fn prop_schedules_are_seed_deterministic() {
    check("schedule_determinism", 100, |rng| {
        let rate_hz = rng.range_f64(10.0, 5_000.0);
        let seed = rng.next_u64();
        let spec = ScheduleSpec {
            process: ArrivalProcess::Poisson { rate_hz },
            requests: rng.range_incl(2, 200),
            seed,
        };
        // same spec, two generator runs: byte-identical
        prop_assert_eq!(spec.generate().to_bytes(), spec.generate().to_bytes());
        // a different seed must actually change a Poisson schedule
        let other = ScheduleSpec { seed: seed.wrapping_add(1), ..spec.clone() };
        prop_assert!(
            spec.generate().to_bytes() != other.generate().to_bytes(),
            "seed change left the schedule identical (rate {rate_hz})"
        );
        Ok(())
    });
}

#[test]
fn poisson_mean_gap_converges_to_rate() {
    // seeded, no wall clock: the sample mean of 4000 exponential gaps must
    // sit within 10% of 1/λ
    for (rate_hz, seed) in [(100.0f64, 1u64), (1_000.0, 2), (20_000.0, 3)] {
        let spec = ScheduleSpec {
            process: ArrivalProcess::Poisson { rate_hz },
            requests: 4_000,
            seed,
        };
        let mean = spec.generate().mean_gap_secs();
        let want = 1.0 / rate_hz;
        assert!(
            (mean - want).abs() / want < 0.10,
            "rate {rate_hz}: mean gap {mean:.3e}, want ≈{want:.3e}"
        );
    }
}
