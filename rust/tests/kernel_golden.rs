//! Golden-value kernel tests — hand-computed expectations for the blocked
//! kernels' trickiest paths (strided depthwise, padded pool at the image
//! boundary, the zero-copy covering fast path with a halo-inflated patch)
//! plus a parallel-vs-serial bitwise-equality property test.

use flexpie::compute::{
    compute_region, compute_tile_set, ComputeConfig, LayerWeights, PatchStore, RegionTensor,
    Tensor, TensorArena, WeightStore,
};
use flexpie::model::{zoo, ConvType, LayerMeta, Model};
use flexpie::partition::geometry::out_tiles;
use flexpie::partition::{Region, Scheme};

fn full_store(t: Tensor) -> PatchStore {
    let r = Region::full(t.h, t.w, t.c);
    let mut s = PatchStore::new();
    s.add(RegionTensor::new(r, t));
    s
}

/// Depthwise 3×3 stride-2 pad-1 over a 5×5×2 input, all-ones filters.
/// Channel 0 holds constant 1.0 (counts the valid taps per window);
/// channel 1 holds `y·5 + x` (sums the clamped window coordinates).
#[test]
fn depthwise_stride2_padded_golden() {
    let l = LayerMeta::conv("dw", ConvType::Depthwise, 5, 5, 2, 2, 3, 2, 1);
    assert_eq!((l.out_h, l.out_w), (3, 3));
    let w = vec![1.0f32; (l.k * l.k * l.out_c) as usize];
    let b = vec![0.5f32, -0.5];
    let lw = LayerWeights { w, b };

    let mut input = Tensor::zeros(5, 5, 2);
    for y in 0..5 {
        for x in 0..5 {
            *input.at_mut(y, x, 0) = 1.0;
            *input.at_mut(y, x, 1) = (y * 5 + x) as f32;
        }
    }
    let store = full_store(input);
    let out = compute_region(&l, &lw, &store, &Region::full(3, 3, 2));

    // channel 0: #valid taps + 0.5 — corners see a 2×2 window, edges 2×3,
    // the center the full 3×3
    let taps = [[4.0, 6.0, 4.0], [6.0, 9.0, 6.0], [4.0, 6.0, 4.0]];
    for oy in 0..3 {
        for ox in 0..3 {
            assert_eq!(
                out.t.at(oy, ox, 0),
                taps[oy as usize][ox as usize] + 0.5,
                "ch0 at ({oy},{ox})"
            );
        }
    }
    // channel 1: sum of y·5+x over the clamped window, minus 0.5
    for oy in 0..3 {
        for ox in 0..3 {
            let mut want = -0.5f32;
            for ky in 0..3 {
                for kx in 0..3 {
                    let (y, x) = (oy * 2 - 1 + ky, ox * 2 - 1 + kx);
                    if (0..5).contains(&y) && (0..5).contains(&x) {
                        want += (y * 5 + x) as f32;
                    }
                }
            }
            assert_eq!(out.t.at(oy, ox, 1), want, "ch1 at ({oy},{ox})");
        }
    }
}

/// Average pool with padding: out-of-bounds taps contribute zero but the
/// divisor stays `k·k` (count-include-pad semantics). A constant-4.0 input
/// makes each output exactly `4·valid_taps/4 = valid_taps`.
#[test]
fn pool_padded_boundary_golden() {
    let l = LayerMeta::conv("p", ConvType::Pool, 4, 4, 1, 1, 2, 2, 1);
    assert_eq!((l.out_h, l.out_w), (3, 3));
    let lw = LayerWeights { w: vec![], b: vec![] };
    let mut input = Tensor::zeros(4, 4, 1);
    for y in 0..4 {
        for x in 0..4 {
            *input.at_mut(y, x, 0) = 4.0;
        }
    }
    let store = full_store(input);
    let out = compute_region(&l, &lw, &store, &Region::full(3, 3, 1));
    let want = [[1.0, 2.0, 1.0], [2.0, 4.0, 2.0], [1.0, 2.0, 1.0]];
    for oy in 0..3 {
        for ox in 0..3 {
            assert_eq!(
                out.t.at(oy, ox, 0),
                want[oy as usize][ox as usize],
                "pool at ({oy},{ox})"
            );
        }
    }
}

/// 1×1 identity conv where the store's single patch is *larger* than the
/// tile's receptive field (a halo-inflated patch, as produced by scatter).
/// Exercises the zero-copy covering fast path's offset arithmetic: the
/// kernel must index into the patch at `y - patch.h0`, not `y - needed.h0`.
#[test]
fn pointwise_identity_on_inflated_patch() {
    let l = LayerMeta::conv("pw", ConvType::Pointwise, 6, 4, 2, 2, 1, 1, 0);
    // identity weights in (ic, oc) order, zero bias
    let mut w = vec![0.0f32; 4];
    w[0] = 1.0; // ic0 -> oc0
    w[3] = 1.0; // ic1 -> oc1
    let lw = LayerWeights { w, b: vec![0.0, 0.0] };

    // patch covers rows 1..5 — a strict superset of the tile's rows 2..4
    let patch_r = Region::new(1, 5, 0, 4, 0, 2);
    let mut t = Tensor::zeros(4, 4, 2);
    for y in 1..5 {
        for x in 0..4 {
            for c in 0..2 {
                *t.at_mut(y - 1, x, c) = (y * 100 + x * 10 + c) as f32;
            }
        }
    }
    let mut store = PatchStore::new();
    store.add(RegionTensor::new(patch_r, t));

    let out_r = Region::new(2, 4, 0, 4, 0, 2);
    let out = compute_region(&l, &lw, &store, &out_r);
    assert_eq!(out.region, out_r);
    for y in 2..4 {
        for x in 0..4 {
            for c in 0..2 {
                assert_eq!(
                    out.t.at(y - 2, x, c),
                    (y * 100 + x * 10 + c) as f32,
                    "identity at ({y},{x},{c})"
                );
            }
        }
    }
}

/// Parallel tile execution must be *bitwise* identical to serial: same
/// tiles, same stores, workers 1 vs 4. Checked across every layer kind in
/// the edgenet zoo model and several tiling schemes.
#[test]
fn parallel_tiles_bitwise_equal_serial() {
    let model = zoo::edgenet(32);
    let weights = WeightStore::for_model(&model, 9);
    let input = Tensor::random(model.layers[0].in_h, model.layers[0].in_w, model.layers[0].in_c, 7);

    // run layer-by-layer on a full-activation store so every layer kind
    // (conv/depthwise/pointwise/pool/dense) gets exercised
    let mut cur = input;
    for (li, l) in model.layers.iter().enumerate() {
        let store = full_store(cur.clone());
        for scheme in [Scheme::InH, Scheme::InW, Scheme::Grid2d] {
            let tiles = out_tiles(l, scheme, 4);
            let items: Vec<(usize, Region)> = tiles.iter().map(|r| (0usize, *r)).collect();
            let stores = [&store];

            let mut arena_s = TensorArena::new(true);
            let serial =
                compute_tile_set(l, &weights.layers[li], &stores, &items, &ComputeConfig::serial(), &mut arena_s);

            let cfg = ComputeConfig { tile_workers: 4, parallel_threshold: 0, reuse_buffers: true };
            let mut arena_p = TensorArena::new(true);
            let par = compute_tile_set(l, &weights.layers[li], &stores, &items, &cfg, &mut arena_p);

            assert_eq!(serial.len(), par.len());
            for (s, p) in serial.iter().zip(par.iter()) {
                assert_eq!(s.region, p.region, "layer {li} {scheme:?}");
                let sb: Vec<u32> = s.t.data.iter().map(|v| v.to_bits()).collect();
                let pb: Vec<u32> = p.t.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(sb, pb, "layer {li} {scheme:?} tile {:?} diverged", s.region);
            }
        }
        // advance the activation via the reference single-tile path
        let full = Region::full(l.out_h, l.out_w, l.out_c);
        cur = compute_region(l, &weights.layers[li], &full_store(cur), &full).t;
    }
}

/// Dense layers write only the x=0 column; a parallel run over row-split
/// dense tiles must still match serial bit-for-bit (regression guard for
/// the reshape_zeroed dispatch).
#[test]
fn parallel_dense_rows_bitwise_equal_serial() {
    let l = LayerMeta::dense("fc", 64, 32, 48);
    let m = Model::new("fc", vec![l.clone()]);
    let ws = WeightStore::for_model(&m, 3);
    let input = Tensor::random(64, 1, 32, 11);
    let store = full_store(input);
    let stores = [&store];
    let items: Vec<(usize, Region)> = (0..4)
        .map(|i| (0usize, Region::new(i * 16, (i + 1) * 16, 0, 1, 0, 48)))
        .collect();

    let mut arena_s = TensorArena::new(true);
    let serial =
        compute_tile_set(&l, &ws.layers[0], &stores, &items, &ComputeConfig::serial(), &mut arena_s);
    let cfg = ComputeConfig { tile_workers: 4, parallel_threshold: 0, reuse_buffers: true };
    let mut arena_p = TensorArena::new(true);
    let par = compute_tile_set(&l, &ws.layers[0], &stores, &items, &cfg, &mut arena_p);
    for (s, p) in serial.iter().zip(par.iter()) {
        let sb: Vec<u32> = s.t.data.iter().map(|v| v.to_bits()).collect();
        let pb: Vec<u32> = p.t.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sb, pb, "dense tile {:?} diverged", s.region);
    }
}
