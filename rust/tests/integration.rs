//! Cross-module integration tests: planner → engine → cluster → serving,
//! plus the paper's qualitative claims on the simulated testbed.

use std::time::Duration;

use flexpie::baselines::{self, Solution};
use flexpie::compute::{Tensor, WeightStore};
use flexpie::cost::CostSource;
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::{Plan, Scheme};
use flexpie::planner::Dpp;
use flexpie::serve::{ServeConfig, Server};

fn tb(nodes: usize, topo: Topology, gbps: f64) -> Testbed {
    Testbed::new(nodes, topo, Bandwidth::gbps(gbps))
}

// ---------------------------------------------------------------------------
// planner → engine → cluster
// ---------------------------------------------------------------------------

#[test]
fn dpp_plans_execute_correctly_across_testbeds() {
    let model = zoo::edgenet(16);
    for nodes in [2usize, 3, 4, 5] {
        for gbps in [5.0, 0.3] {
            let testbed = tb(nodes, Topology::Ring, gbps);
            let cost = CostSource::analytic(&testbed);
            let plan = Dpp::new(&model, &cost).plan();
            let diff = engine::verify_plan(&model, &plan, &testbed, 42);
            assert_eq!(diff, 0.0, "n={nodes} bw={gbps} plan={}", plan.render());
        }
    }
}

#[test]
fn all_baseline_plans_execute_correctly() {
    let model = zoo::edgenet(16);
    let testbed = tb(4, Topology::Ring, 1.0);
    let cost = CostSource::analytic(&testbed);
    for sol in Solution::ALL {
        let plan = sol.plan(&model, &cost);
        let diff = engine::verify_plan(&model, &plan, &testbed, 9);
        assert_eq!(diff, 0.0, "{sol}");
    }
}

#[test]
fn larger_edgenet_distributed_execution() {
    let model = zoo::edgenet(32);
    let testbed = tb(4, Topology::Ps, 1.0);
    let cost = CostSource::analytic(&testbed);
    let plan = Dpp::new(&model, &cost).plan();
    assert_eq!(engine::verify_plan(&model, &plan, &testbed, 3), 0.0);
}

// ---------------------------------------------------------------------------
// the paper's qualitative claims (§4) on the simulator
// ---------------------------------------------------------------------------

/// Fig 7 claim: on the 4-node testbed, 2D-grid is the best *fixed* scheme
/// for MobileNet-class convnets (balanced 2×2 cells), OutC the worst (full
/// feature-map all-gather per layer).
#[test]
fn four_node_fixed_scheme_ordering_mobilenet() {
    let model = zoo::mobilenet_v1(224, 1000);
    let testbed = tb(4, Topology::Ring, 1.0);
    let _cost = CostSource::analytic(&testbed);
    let t = |s: Scheme| {
        engine::evaluate(&model, &Plan::uniform(s, model.n_layers()), &testbed).total
    };
    let (grid, outc, inh) = (t(Scheme::Grid2d), t(Scheme::OutC), t(Scheme::InH));
    assert!(grid < outc, "grid {grid} !< outc {outc}");
    assert!(inh < outc, "inh {inh} !< outc {outc}");
}

/// Fig 9 claim: on 3 nodes the 2D-grid collapses (one node does 2× work),
/// falling behind One-dim.
#[test]
fn three_node_grid_penalty() {
    let model = zoo::mobilenet_v1(224, 1000);
    let testbed = tb(3, Topology::Ring, 5.0);
    let cost = CostSource::analytic(&testbed);
    let t = |s: Scheme| {
        engine::evaluate(&model, &Plan::uniform(s, model.n_layers()), &testbed).total
    };
    assert!(t(Scheme::Grid2d) > t(Scheme::InH));
    // and FlexPie beats them all
    let flex = Dpp::new(&model, &cost).plan();
    assert!(flex.est_cost < t(Scheme::InH));
}

/// §4.1 Limitation: BERT gains little from FlexPie — row-split matmuls are
/// already balanced and sync-free, so all solutions are close.
#[test]
fn bert_limitation_small_speedup() {
    let model = zoo::bert_base(128);
    let testbed = tb(4, Topology::Ring, 5.0);
    let cost = CostSource::analytic(&testbed);
    let flex = Dpp::new(&model, &cost).plan();
    let best_fixed = Scheme::ALL
        .iter()
        .map(|&s| engine::evaluate(&model, &Plan::uniform(s, model.n_layers()), &testbed).total)
        .fold(f64::INFINITY, f64::min);
    let speedup = best_fixed / flex.est_cost;
    assert!(
        speedup < 1.6,
        "BERT speedup {speedup} unexpectedly large (paper: ~none)"
    );
    // ... while MobileNet's speedup over its best fixed scheme is larger.
    let mn = zoo::mobilenet_v1(224, 1000);
    let mn_tb = tb(4, Topology::Ring, 0.5);
    let mn_cost = CostSource::analytic(&mn_tb);
    let mn_flex = Dpp::new(&mn, &mn_cost).plan();
    let mn_best_fixed = Scheme::ALL
        .iter()
        .map(|&s| engine::evaluate(&mn, &Plan::uniform(s, mn.n_layers()), &mn_tb).total)
        .fold(f64::INFINITY, f64::min);
    assert!(mn_best_fixed / mn_flex.est_cost > speedup);
}

/// Headline claim: FlexPie ≥ every baseline on every (model, testbed) cell,
/// with meaningful spread somewhere (the paper reports 1.10–2.39×).
#[test]
fn flexpie_dominates_baselines_paper_grid_sample() {
    let mut max_speedup = 1.0f64;
    for (model, trunc) in [
        (zoo::mobilenet_v1(224, 1000), 29),
        (zoo::resnet18(224, 1000), 20),
    ] {
        let model = model.truncated(trunc);
        for nodes in [4usize, 3] {
            for gbps in [5.0, 0.5] {
                let testbed = tb(nodes, Topology::Ring, gbps);
                let cost = CostSource::analytic(&testbed);
                let flex = engine::evaluate(
                    &model,
                    &Solution::FlexPie.plan(&model, &cost),
                    &testbed,
                )
                .total;
                for sol in Solution::BASELINES {
                    let t =
                        engine::evaluate(&model, &sol.plan(&model, &cost), &testbed).total;
                    assert!(
                        flex <= t + 1e-9,
                        "{sol} beat FlexPie on {} n={nodes} bw={gbps}",
                        model.name
                    );
                    max_speedup = max_speedup.max(t / flex);
                }
            }
        }
    }
    assert!(max_speedup > 1.3, "no meaningful speedup anywhere: {max_speedup}");
}

/// Layer fusion matters more at low bandwidth (the §2.3 trade-off).
#[test]
fn fusion_count_increases_as_bandwidth_drops() {
    let model = zoo::mobilenet_v1(224, 1000);
    let count_nt = |gbps: f64| {
        let testbed = tb(4, Topology::Ring, gbps);
        let cost = CostSource::analytic(&testbed);
        Dpp::new(&model, &cost).plan().n_fused_layers()
    };
    let high = count_nt(50.0);
    let low = count_nt(0.05);
    assert!(low >= high, "NT layers: low-bw {low} < high-bw {high}");
    assert!(low > 0, "no fusion even at 50 Mb/s");
}

// ---------------------------------------------------------------------------
// serving path
// ---------------------------------------------------------------------------

#[test]
fn serving_end_to_end_with_dpp_plan() {
    let model = zoo::edgenet(16);
    let testbed = tb(4, Topology::Ring, 5.0);
    let cost = CostSource::analytic(&testbed);
    let plan = Dpp::new(&model, &cost).plan();
    let weights = WeightStore::for_model(&model, 42);
    let reference_ws = WeightStore::for_model(&model, 42);

    let server = Server::start(
        model.clone(),
        plan,
        weights,
        testbed,
        ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(5),
            queue_depth: 64,
            ..ServeConfig::default()
        },
    );
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        rxs.push((i, server.submit(Tensor::random(16, 16, 3, i)).unwrap()));
    }
    for (i, rx) in rxs {
        let resp = rx.recv().unwrap();
        let reference = flexpie::compute::run_reference(
            &model,
            &reference_ws,
            &Tensor::random(16, 16, 3, i),
        );
        assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "request {i}");
        assert!(resp.virtual_time > 0.0);
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 12);
}

// ---------------------------------------------------------------------------
// baselines coherence
// ---------------------------------------------------------------------------

#[test]
fn solution_hierarchy_is_ordered() {
    // layerwise ⊆ flexpie and fused ⊆ flexpie search spaces ⇒ cost ordering.
    let model = zoo::mobilenet_v1(224, 1000).truncated(13);
    let testbed = tb(4, Topology::Ps, 0.5);
    let cost = CostSource::analytic(&testbed);
    let flex = Solution::FlexPie.plan(&model, &cost).est_cost;
    let lw = baselines::layerwise(&model, &cost).est_cost;
    let fused = baselines::fused_layer(&model, &cost).est_cost;
    let fixed_best = [Scheme::InH, Scheme::InW, Scheme::OutC, Scheme::Grid2d]
        .iter()
        .map(|&s| baselines::fixed(&model, s, &cost).est_cost)
        .fold(f64::INFINITY, f64::min);
    assert!(flex <= lw + 1e-12);
    assert!(flex <= fused + 1e-12);
    assert!(lw <= fixed_best + 1e-12);
    assert!(fused <= fixed_best + 1e-12);
}
