//! Property-based tests over the coordinator's core invariants (routing
//! geometry, batching of work across nodes, plan state machine), using the
//! in-repo property driver (`flexpie::util::prop`).
//!
//! Replay a failure with `FLEXPIE_PROP_SEED=<seed> cargo test --test proptests`.

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::cost::query::{boundary_query, compute_query_tiles};
use flexpie::cost::CostSource;
use flexpie::model::{zoo, ConvType, LayerMeta};
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::geometry::{in_regions, out_tiles};
use flexpie::partition::inflate::BlockGeometry;
use flexpie::partition::{
    intersection_volume, union_volume, Mode, Plan, PlanStep, Region, Scheme,
};
use flexpie::planner::exhaustive::plan_cost;
use flexpie::planner::Dpp;
use flexpie::util::prop::check;
use flexpie::util::rng::Rng;
use flexpie::{prop_assert, prop_assert_eq};

fn random_layer(rng: &mut Rng) -> LayerMeta {
    let conv_t = *rng.pick(&[
        ConvType::Standard,
        ConvType::Depthwise,
        ConvType::Pointwise,
        ConvType::Pool,
        ConvType::Dense,
    ]);
    match conv_t {
        ConvType::Dense => {
            let rows = *rng.pick(&[1i64, 4, 16, 64]);
            LayerMeta::dense("p_fc", rows, *rng.pick(&[8i64, 32, 128]), *rng.pick(&[4i64, 10, 64]))
        }
        _ => {
            let h = *rng.pick(&[4i64, 7, 8, 14, 16, 28]);
            let c_in = *rng.pick(&[1i64, 3, 8, 16]);
            let (k, p) = match conv_t {
                ConvType::Pointwise => (1, 0),
                _ => *rng.pick(&[(3i64, 1i64), (3, 0), (5, 2)]),
            };
            if h + 2 * p < k {
                return LayerMeta::conv("p", conv_t, h, h, c_in, c_in, 1, 1, 0);
            }
            let s = if rng.bool(0.3) { 2 } else { 1 };
            let c_out = match conv_t {
                ConvType::Depthwise | ConvType::Pool => c_in,
                _ => *rng.pick(&[4i64, 8, 16]),
            };
            LayerMeta::conv("p", conv_t, h, h, c_in, c_out, k, s, p)
        }
    }
}

fn random_scheme(rng: &mut Rng) -> Scheme {
    *rng.pick(&Scheme::ALL)
}

#[test]
fn prop_tiles_partition_output_space() {
    check("tiles_partition_output_space", 300, |rng| {
        let layer = random_layer(rng);
        let nodes = rng.range_incl(1, 6);
        let scheme = random_scheme(rng);
        let tiles = out_tiles(&layer, scheme, nodes);
        let total: i64 = tiles.iter().map(|t| union_volume(t)).sum();
        prop_assert_eq!(total, layer.out_volume());
        for a in 0..nodes {
            for b in (a + 1)..nodes {
                prop_assert!(
                    intersection_volume(&tiles[a], &tiles[b]) == 0,
                    "tiles {a},{b} overlap for {layer:?} {scheme} n={nodes}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_in_region_covers_receptive_field() {
    check("in_region_covers_receptive_field", 300, |rng| {
        let layer = random_layer(rng);
        let nodes = rng.range_incl(1, 6);
        let scheme = random_scheme(rng);
        let tiles = out_tiles(&layer, scheme, nodes);
        for t in &tiles {
            for need in in_regions(&layer, t) {
                let valid = Region::full(layer.in_h, layer.in_w, layer.in_c);
                prop_assert!(
                    valid.contains(&need),
                    "in_region escapes valid input: {need:?} vs {valid:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_union_volume_bounds() {
    check("union_volume_bounds", 500, |rng| {
        let n = rng.range_incl(1, 5);
        let mut regions = Vec::new();
        for _ in 0..n {
            let h0 = rng.below(10) as i64;
            let w0 = rng.below(10) as i64;
            let c0 = rng.below(4) as i64;
            regions.push(Region::new(
                h0,
                h0 + rng.below(8) as i64,
                w0,
                w0 + rng.below(8) as i64,
                c0,
                c0 + rng.below(4) as i64,
            ));
        }
        let u = union_volume(&regions);
        let sum: i64 = regions.iter().map(Region::volume).sum();
        let max = regions.iter().map(Region::volume).max().unwrap_or(0);
        prop_assert!(u <= sum, "union {u} > sum {sum}");
        prop_assert!(u >= max, "union {u} < max {max}");
        Ok(())
    });
}

#[test]
fn prop_block_inflation_monotone_and_anchored() {
    check("block_inflation_monotone", 200, |rng| {
        // same-shape conv chains so any span is geometrically valid
        let h = *rng.pick(&[8i64, 14, 16, 28]);
        let c = *rng.pick(&[4i64, 8]);
        let span = rng.range_incl(1, 4);
        let model = zoo::tiny_chain(span, h, c);
        let nodes = rng.range_incl(2, 5);
        let scheme = random_scheme(rng);
        let geo = BlockGeometry::new(&model.layers, scheme, nodes);
        let mut prev = f64::INFINITY;
        for l in 0..span {
            let infl = geo.inflation(&model.layers, l);
            prop_assert!(infl >= 1.0 - 1e-12, "inflation < 1 at layer {l}");
            prop_assert!(infl <= prev + 1e-12, "inflation not decreasing towards end");
            prev = infl;
        }
        prop_assert!((geo.inflation(&model.layers, span - 1) - 1.0).abs() < 1e-12);
        Ok(())
    });
}

#[test]
fn prop_boundary_messages_deliver_exactly_what_is_missing() {
    check("boundary_delivers_missing", 200, |rng| {
        let h = *rng.pick(&[8i64, 14, 16]);
        let c = *rng.pick(&[4i64, 8]);
        let producer = LayerMeta::conv("a", ConvType::Standard, h, h, c, c, 3, 1, 1);
        let consumer = LayerMeta::conv("b", ConvType::Standard, h, h, c, c, 3, 1, 1);
        let nodes = rng.range_incl(2, 5);
        let p_from = random_scheme(rng);
        let p_to = random_scheme(rng);
        let tb = Testbed::new(nodes, Topology::Mesh, Bandwidth::gbps(1.0));
        let geo = BlockGeometry::new(std::slice::from_ref(&consumer), p_to, nodes);
        let q = boundary_query(&producer, p_from, &consumer, p_to, &geo.entry_need, &tb);
        // each node's received bytes == vol(need \ have) × 4
        let have = out_tiles(&producer, p_from, nodes);
        for b in 0..nodes {
            let need_vol = union_volume(&geo.entry_need[b]);
            let held = intersection_volume(&have[b], &geo.entry_need[b]);
            let expect = (need_vol - held) as u64 * 4;
            let got: u64 = (0..nodes).map(|a| q.msgs[a * nodes + b]).sum();
            prop_assert_eq!(got, expect);
        }
        Ok(())
    });
}

#[test]
fn prop_compute_query_flops_conservation() {
    check("compute_query_flops", 200, |rng| {
        let layer = random_layer(rng);
        let nodes = rng.range_incl(1, 6);
        let scheme = random_scheme(rng);
        let tb = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));
        let tiles = out_tiles(&layer, scheme, nodes);
        let q = compute_query_tiles(&layer, &tiles, scheme, &tb);
        let total: f64 = q.per_node_flops[..nodes].iter().sum();
        // canonical tiles partition the output → per-node flops sum to the
        // layer's total flops (speed factors are 1.0 here)
        prop_assert!(
            (total - layer.flops()).abs() <= 1e-6 * layer.flops().max(1.0),
            "flops {total} vs layer {}",
            layer.flops()
        );
        Ok(())
    });
}

#[test]
fn prop_exchange_time_monotone_in_bytes_and_bandwidth() {
    check("exchange_monotonicity", 200, |rng| {
        let nodes = rng.range_incl(2, 6);
        let topo = *rng.pick(&Topology::ALL);
        let mut msgs = vec![0u64; nodes * nodes];
        for a in 0..nodes {
            for b in 0..nodes {
                if a != b && rng.bool(0.5) {
                    msgs[a * nodes + b] = rng.below(1_000_000) as u64;
                }
            }
        }
        let fast = Testbed::new(nodes, topo, Bandwidth::gbps(5.0));
        let slow = Testbed::new(nodes, topo, Bandwidth::gbps(0.5));
        let t_fast = fast.exchange_time(&msgs);
        let t_slow = slow.exchange_time(&msgs);
        prop_assert!(t_slow >= t_fast);
        // doubling every message can't reduce time
        let doubled: Vec<u64> = msgs.iter().map(|&m| m * 2).collect();
        prop_assert!(fast.exchange_time(&doubled) >= t_fast);
        Ok(())
    });
}

#[test]
fn prop_plan_cost_decomposition() {
    check("plan_cost_decomposition", 100, |rng| {
        let model = zoo::tiny_chain(rng.range_incl(1, 5), 12, 8);
        let nodes = rng.range_incl(2, 5);
        let tb = Testbed::new(nodes, *rng.pick(&Topology::ALL), Bandwidth::gbps(1.0));
        let cost = CostSource::analytic(&tb);
        // random valid plan: random blocks, one scheme per block
        let plan = random_plan(rng, model.n_layers());
        let pc = plan_cost(&model, &plan, &cost);
        prop_assert!((pc.total - pc.compute - pc.sync).abs() < 1e-12);
        prop_assert_eq!(pc.per_layer_compute.len(), model.n_layers());
        prop_assert_eq!(pc.per_boundary_sync.len(), plan.blocks().len() + 1);
        prop_assert!(pc.total > 0.0);
        Ok(())
    });
}

fn random_plan(rng: &mut Rng, n: usize) -> Plan {
    let mut steps = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        let span = rng.range_incl(1, (n - i).min(3));
        let scheme = random_scheme(rng);
        for _ in 0..span - 1 {
            steps.push(PlanStep { scheme, mode: Mode::NT });
        }
        steps.push(PlanStep { scheme, mode: Mode::T });
        i += span;
    }
    let plan = Plan { steps, est_cost: f64::NAN };
    plan.validate().expect("random plan invalid");
    plan
}

#[test]
fn prop_random_plans_execute_to_reference() {
    // The heavyweight end-to-end property: ANY valid plan executed on the
    // simulated cluster reproduces the single-node reference exactly.
    check("random_plans_execute_to_reference", 25, |rng| {
        let model = zoo::edgenet(16);
        let nodes = rng.range_incl(2, 5);
        let plan = random_plan(rng, model.n_layers());
        let ws = WeightStore::for_model(&model, rng.next_u64());
        let input = Tensor::random(16, 16, 3, rng.next_u64());
        let reference = run_reference(&model, &ws, &input);
        let run =
            flexpie::cluster::run_distributed(&model, &plan, &ws, &input, nodes);
        let diff = reference.max_abs_diff(&run.output);
        prop_assert!(
            diff == 0.0,
            "plan {} on {nodes} nodes diverged by {diff}",
            plan.render()
        );
        Ok(())
    });
}

#[test]
fn prop_dpp_dominates_random_plans() {
    // DPP's estimate is a lower bound over every plan in its search space.
    check("dpp_dominates_random_plans", 40, |rng| {
        let model = zoo::tiny_chain(rng.range_incl(2, 5), 14, 8);
        let nodes = rng.range_incl(2, 5);
        let tb = Testbed::new(nodes, *rng.pick(&Topology::ALL), Bandwidth::gbps(1.0));
        let cost = CostSource::analytic(&tb);
        let dpp = Dpp::new(&model, &cost).plan();
        let rand_plan = random_plan(rng, model.n_layers());
        let rc = plan_cost(&model, &rand_plan, &cost).total;
        prop_assert!(
            dpp.est_cost <= rc + 1e-9,
            "random plan {} ({rc}) beat DPP ({})",
            rand_plan.render(),
            dpp.est_cost
        );
        Ok(())
    });
}

#[test]
fn prop_model_zoo_truncations_always_plannable() {
    check("zoo_truncations_plannable", 30, |rng| {
        let full = match rng.below(3) {
            0 => zoo::mobilenet_v1(224, 1000),
            1 => zoo::resnet18(224, 1000),
            _ => zoo::bert_base(128),
        };
        let n = rng.range_incl(1, full.n_layers().min(10));
        let model = full.truncated(n);
        let tb = Testbed::new(
            rng.range_incl(2, 6),
            *rng.pick(&Topology::ALL),
            Bandwidth::gbps(rng.range_f64(0.1, 6.0)),
        );
        let cost = CostSource::analytic(&tb);
        let plan = Dpp::new(&model, &cost).plan();
        plan.validate().map_err(|e| e.to_string())?;
        prop_assert!(plan.est_cost.is_finite() && plan.est_cost > 0.0);
        Ok(())
    });
}
