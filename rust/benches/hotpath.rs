//! Hot-path micro-benchmarks — the §Perf profiling targets.
//!
//! The planner's inner loop is (feature build → estimator predict) and
//! (tile math → message matrix → topology schedule); the engine's is the
//! native conv kernel. Each is measured in isolation so EXPERIMENTS.md §Perf
//! can attribute end-to-end improvements.

use flexpie::compute::{compute_region, PatchStore, RegionTensor, Tensor, WeightStore};
use flexpie::cost::gbdt::{Gbdt, GbdtParams};
use flexpie::cost::query::{boundary_query, compute_query_tiles};
use flexpie::cost::tracegen::{generate, TraceConfig};
use flexpie::cost::{CostSource, NF};
use flexpie::model::{zoo, ConvType, LayerMeta, Model};
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::geometry::out_tiles;
use flexpie::partition::inflate::BlockGeometry;
use flexpie::partition::{union_volume, Region, Scheme};
use flexpie::planner::exhaustive::plan_cost;
use flexpie::partition::Plan;
use flexpie::util::bench::{black_box, emit_result, BenchRunner};
use flexpie::util::json::Json;

fn main() {
    let r = BenchRunner::new("hotpath");
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));

    // --- geometry ---------------------------------------------------------
    let layer = LayerMeta::conv("l", ConvType::Standard, 56, 56, 128, 128, 3, 1, 1);
    r.bench("out_tiles/4nodes", || out_tiles(&layer, Scheme::Grid2d, 4));
    let regions: Vec<Region> =
        (0..6).map(|i| Region::new(i, i + 10, 0, 56, 0, 128)).collect();
    r.bench("union_volume/6boxes", || union_volume(&regions));
    let chain = zoo::tiny_chain(4, 56, 64);
    r.bench("block_geometry/span4", || BlockGeometry::new(&chain.layers, Scheme::InH, 4));

    // --- queries ----------------------------------------------------------
    let tiles = out_tiles(&layer, Scheme::InH, 4);
    r.bench("compute_query", || compute_query_tiles(&layer, &tiles, Scheme::InH, &tb));
    let next = layer.clone();
    let geo = BlockGeometry::new(std::slice::from_ref(&next), Scheme::InW, 4);
    r.bench("boundary_query(cross-scheme)", || {
        boundary_query(&layer, Scheme::InH, &next, Scheme::InW, &geo.entry_need, &tb)
    });

    // --- estimators -------------------------------------------------------
    let traces = generate(&TraceConfig { samples: 3_000, ..Default::default() });
    let params = GbdtParams { n_trees: 200, ..Default::default() };
    let model = Gbdt::train(&traces.compute.x, &traces.compute.y, NF, &params);
    let probe: Vec<f64> = traces.compute.x[..NF].to_vec();
    r.bench("gbdt_predict/200trees", || model.predict(black_box(&probe)));

    // --- topology schedule --------------------------------------------------
    let mut msgs = vec![0u64; 16];
    for a in 0..4 {
        for b in 0..4 {
            if a != b {
                msgs[a * 4 + b] = 100_000;
            }
        }
    }
    r.bench("exchange_time/ring", || tb.exchange_time(black_box(&msgs)));

    // --- plan costing + planning ------------------------------------------
    let mobilenet = zoo::mobilenet_v1(224, 1000);
    let cost = CostSource::analytic(&tb);
    let plan = Plan::uniform(Scheme::Grid2d, mobilenet.n_layers());
    r.bench("plan_cost/mobilenet", || plan_cost(&mobilenet, &plan, &cost).total);
    let dpp = flexpie::planner::Dpp::new(&mobilenet, &cost);
    r.bench("dpp_plan/mobilenet", || dpp.plan().est_cost);

    // --- native kernel ------------------------------------------------------
    let conv = LayerMeta::conv("c", ConvType::Standard, 32, 32, 16, 16, 3, 1, 1);
    let m = Model::new("one", vec![conv.clone()]);
    let ws = WeightStore::for_model(&m, 1);
    let mut store = PatchStore::new();
    store.add(RegionTensor::new(Region::full(32, 32, 16), Tensor::random(32, 32, 16, 2)));
    let out_r = Region::full(32, 32, 16);
    r.bench("native_conv/32x32x16x16", || {
        compute_region(&conv, &ws.layers[0], &store, &out_r).t.data[0]
    });
    let pw = LayerMeta::conv("pw", ConvType::Pointwise, 32, 32, 64, 64, 1, 1, 0);
    let mpw = Model::new("pw", vec![pw.clone()]);
    let wpw = WeightStore::for_model(&mpw, 2);
    let mut store_pw = PatchStore::new();
    store_pw.add(RegionTensor::new(Region::full(32, 32, 64), Tensor::random(32, 32, 64, 3)));
    let out_pw = Region::full(32, 32, 64);
    let s_pw = r.bench("native_pointwise/32x32x64x64", || {
        compute_region(&pw, &wpw.layers[0], &store_pw, &out_pw).t.data[0]
    });

    // the ISSUE 8 reference shape: one full 56×56×128→128 3×3 conv layer —
    // the dominant kernel in the mobilenet-class zoo models
    let big = LayerMeta::conv("big", ConvType::Standard, 56, 56, 128, 128, 3, 1, 1);
    let mb = Model::new("big", vec![big.clone()]);
    let wb = WeightStore::for_model(&mb, 4);
    let mut store_big = PatchStore::new();
    store_big.add(RegionTensor::new(Region::full(56, 56, 128), Tensor::random(56, 56, 128, 5)));
    let out_big = Region::full(56, 56, 128);
    let s_big = r.bench("native_conv/56x56x128x128", || {
        compute_region(&big, &wb.layers[0], &store_big, &out_big).t.data[0]
    });

    emit_result(vec![
        ("bench", Json::Str("hotpath".into())),
        ("conv56_mean_s", Json::Num(s_big.mean_secs())),
        ("pointwise32_mean_s", Json::Num(s_pw.mean_secs())),
        ("conv56_gflops", Json::Num({
            let flops = 2.0 * 56.0 * 56.0 * 128.0 * 128.0 * 9.0;
            flops / s_big.mean_secs() / 1e9
        })),
    ]);
}
