//! Fig 8 reproduction: summative performance score per solution
//! (`score = mean over test cases of min(t₁..t₆)/tᵢ`), on the 4-node and
//! 3-node grids.
//!
//! Paper shape to check: FlexPie scores 1.0 (or within estimator noise of
//! it) on both testbeds; fixed schemes score lowest.

use flexpie::bench::{fig7_9, fig8, fig8_table, BenchOpts, CostKind};

fn main() {
    let mut opts = BenchOpts::default();
    if std::env::var("FLEXPIE_BENCH_COST").as_deref() == Ok("analytic") {
        opts.cost = CostKind::Analytic;
    }
    let c4 = fig7_9(4, &opts);
    let c3 = fig7_9(3, &opts);
    let s4 = fig8(&c4, &opts);
    let s3 = fig8(&c3, &opts);
    println!("== Fig 8: performance score ==");
    fig8_table(&s4, &s3).print();
}
