//! Design ablations (DESIGN.md §6): what each piece of FlexPie buys.
//!
//! * GBDT-CE planning regret vs the analytic oracle
//! * fusion disabled (layerwise-only)
//! * OutC removed (spatial schemes only)
//! * block span capped
//!
//! Plus Thm-1-scale evidence: DPP vs exhaustive plan cost on a small model.

use flexpie::bench::{ablation, ablation_table, scaling, scaling_table, BenchOpts};
use flexpie::cost::CostSource;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::Scheme;
use flexpie::planner::exhaustive::{exhaustive_plan, plan_cost};
use flexpie::planner::Dpp;

fn main() {
    let opts = BenchOpts::default();
    println!("== Ablations (evaluated on the analytic simulator) ==");
    ablation_table(&ablation(&opts)).print();

    println!("\n== Node-count scaling (Ring @ 1 Gb/s) ==");
    scaling_table(&scaling(&opts)).print();

    println!("\n== Thm 1 spot-check (DPP vs exhaustive, edgenet-6) ==");
    let model = zoo::edgenet(16).truncated(6);
    for gbps in [5.0, 0.5] {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(gbps));
        let cost = CostSource::analytic(&tb);
        let dpp = Dpp::new(&model, &cost).plan();
        let brute = exhaustive_plan(&model, &cost, &Scheme::ALL);
        let dpp_cost = plan_cost(&model, &dpp, &cost).total;
        println!(
            "  bw={gbps:>4} Gb/s  dpp={:.6} ms  exhaustive={:.6} ms  equal={}",
            dpp_cost * 1e3,
            brute.est_cost * 1e3,
            (dpp_cost - brute.est_cost).abs() < 1e-12
        );
    }
}
