//! Kernel + allocation regression bench — the §Perf evidence for the
//! blocked-kernel rewrite.
//!
//! Three measurements, one `RESULT {...}` JSON line (CI folds it into
//! `BENCH_pr8.json`):
//!
//! 1. **Kernel speedups** — the pre-rewrite scalar kernels are embedded
//!    here verbatim as baselines and every comparison first asserts the
//!    blocked kernels produce *bitwise* identical outputs, so the speedup
//!    numbers can never drift away from correctness.
//! 2. **Tile parallelism** — [`compute_tile_set`] serial vs a 4-worker
//!    pool over an 8-way InH split of the 56×56×128 conv.
//! 3. **Allocation regression guard** — a counting global allocator plus
//!    the pipeline arenas' own counters measure the steady-state serving
//!    path (edgenet through [`BlockPipeline`]) with buffer reuse on vs
//!    off. The arena-level ratio is asserted `>= FLEXPIE_ALLOC_GUARD`
//!    (default 10) so a future change that reintroduces per-item churn
//!    fails CI, not just a dashboard.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use flexpie::cluster::pipeline::BlockPipeline;
use flexpie::compute::{
    compute_region, compute_tile_set, unclamped_in_region, ComputeConfig, LayerWeights,
    PatchStore, RegionTensor, Tensor, TensorArena, WeightStore,
};
use flexpie::model::{zoo, ConvType, LayerMeta, Model};
use flexpie::partition::geometry::{in_region, out_tiles};
use flexpie::partition::{Plan, Region, Scheme};
use flexpie::util::bench::{black_box, emit_result, BenchRunner};
use flexpie::util::json::Json;

// --- counting allocator ----------------------------------------------------

struct CountingAlloc;

static HEAP_ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        HEAP_ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        HEAP_ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        HEAP_ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn heap_allocs() -> u64 {
    HEAP_ALLOCS.load(Ordering::Relaxed)
}

// --- the pre-rewrite kernels, verbatim (the speedup baselines) -------------

fn naive_conv2d(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_w_stride = (in_r.w1 - in_r.w0) as usize * in_c;
    let mut acc = vec![0.0f32; oc_len];

    for oy in out_r.h0..out_r.h1 {
        for ox in out_r.w0..out_r.w1 {
            acc.copy_from_slice(bias);
            for ky in 0..k {
                let y = oy * s - p + ky;
                if y < 0 || y >= layer.in_h {
                    continue;
                }
                let row_base = (y - in_r.h0) as usize * in_w_stride;
                for kx in 0..k {
                    let x = ox * s - p + kx;
                    if x < 0 || x >= layer.in_w {
                        continue;
                    }
                    let px_base =
                        row_base + (x - in_r.w0) as usize * in_c + (0i64 - in_r.c0) as usize;
                    let xs = &input.data[px_base..px_base + in_c];
                    let w_tap = ((ky * k + kx) as usize) * in_c * out_c;
                    for (ic, &xv) in xs.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow =
                            &weights.w[w_tap + ic * out_c + oc0..w_tap + ic * out_c + oc1];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            let out_base =
                ((oy - out_r.h0) * (out_r.w1 - out_r.w0) + (ox - out_r.w0)) as usize * oc_len;
            out.data[out_base..out_base + oc_len].copy_from_slice(&acc);
        }
    }
}

fn naive_pointwise(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_w_stride = (in_r.w1 - in_r.w0) as usize * in_c;
    let ow_len = (out_r.w1 - out_r.w0) as usize;
    let mut acc = vec![0.0f32; 4 * oc_len];

    for oy in out_r.h0..out_r.h1 {
        let row_base = (oy - in_r.h0) as usize * in_w_stride;
        let mut ox = out_r.w0;
        while ox < out_r.w1 {
            let blk = ((out_r.w1 - ox) as usize).min(4);
            for b in 0..blk {
                acc[b * oc_len..(b + 1) * oc_len].copy_from_slice(bias);
            }
            for ic in 0..in_c {
                let wrow = &weights.w[ic * out_c + oc0..ic * out_c + oc1];
                for b in 0..blk {
                    let xv = input.data[row_base + (ox + b as i64 - in_r.w0) as usize * in_c + ic];
                    if xv == 0.0 {
                        continue;
                    }
                    let a = &mut acc[b * oc_len..(b + 1) * oc_len];
                    for (aj, &wv) in a.iter_mut().zip(wrow) {
                        *aj += xv * wv;
                    }
                }
            }
            for b in 0..blk {
                let out_base =
                    ((oy - out_r.h0) as usize * ow_len + (ox - out_r.w0) as usize + b) * oc_len;
                out.data[out_base..out_base + oc_len]
                    .copy_from_slice(&acc[b * oc_len..(b + 1) * oc_len]);
            }
            ox += blk as i64;
        }
    }
}

fn naive_depthwise(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let out_c = layer.out_c as usize;
    let c0 = out_r.c0;
    let c_len = (out_r.c1 - out_r.c0) as usize;
    let in_c_len = (in_r.c1 - in_r.c0) as usize;
    let in_w_stride = (in_r.w1 - in_r.w0) as usize * in_c_len;
    let bias = &weights.b[c0 as usize..out_r.c1 as usize];
    let mut acc = vec![0.0f32; c_len];

    for oy in out_r.h0..out_r.h1 {
        for ox in out_r.w0..out_r.w1 {
            acc.copy_from_slice(bias);
            for ky in 0..k {
                let y = oy * s - p + ky;
                if y < 0 || y >= layer.in_h {
                    continue;
                }
                for kx in 0..k {
                    let x = ox * s - p + kx;
                    if x < 0 || x >= layer.in_w {
                        continue;
                    }
                    let px = (y - in_r.h0) as usize * in_w_stride
                        + (x - in_r.w0) as usize * in_c_len
                        + (c0 - in_r.c0) as usize;
                    let xs = &input.data[px..px + c_len];
                    let wq = ((ky * k + kx) as usize) * out_c + c0 as usize;
                    let ws = &weights.w[wq..wq + c_len];
                    for ((a, &xv), &wv) in acc.iter_mut().zip(xs).zip(ws) {
                        *a += xv * wv;
                    }
                }
            }
            let out_base =
                ((oy - out_r.h0) * (out_r.w1 - out_r.w0) + (ox - out_r.w0)) as usize * c_len;
            out.data[out_base..out_base + c_len].copy_from_slice(&acc);
        }
    }
}

fn naive_dense(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    for row in out_r.h0..out_r.h1 {
        for oc in out_r.c0..out_r.c1 {
            let mut acc = weights.b[oc as usize];
            for ic in 0..layer.in_c {
                acc += weights.w[(ic * layer.out_c + oc) as usize]
                    * input.at(row - in_r.h0, 0, ic - in_r.c0);
            }
            *out.at_mut(row - out_r.h0, 0, oc - out_r.c0) = acc;
        }
    }
}

/// The pre-rewrite `compute_region`: always extract a dense receptive-field
/// hull, then run the scalar kernel over it.
fn naive_region(
    layer: &LayerMeta,
    weights: &LayerWeights,
    store: &PatchStore,
    out_r: &Region,
) -> Tensor {
    let valid = Region::full(layer.in_h, layer.in_w, layer.in_c);
    let needed = valid.intersect(&in_region(layer, out_r));
    let raw = unclamped_in_region(layer, out_r);
    let input = store.extract(&raw, &needed, true);
    let mut out =
        Tensor::zeros(out_r.h1 - out_r.h0, out_r.w1 - out_r.w0, out_r.c1 - out_r.c0);
    match layer.conv_t {
        ConvType::Standard => naive_conv2d(layer, weights, &input, &raw, out_r, &mut out),
        ConvType::Pointwise => naive_pointwise(layer, weights, &input, &raw, out_r, &mut out),
        ConvType::Depthwise => naive_depthwise(layer, weights, &input, &raw, out_r, &mut out),
        ConvType::Dense | ConvType::Attention => {
            naive_dense(layer, weights, &input, &raw, out_r, &mut out)
        }
        ConvType::Pool => unreachable!("pool is not a speedup target"),
    }
    out
}

// --- harness ---------------------------------------------------------------

fn full_store(t: Tensor) -> PatchStore {
    let r = Region::full(t.h, t.w, t.c);
    let mut s = PatchStore::new();
    s.add(RegionTensor::new(r, t));
    s
}

fn assert_bitwise_eq(label: &str, a: &Tensor, b: &Tensor) {
    assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c), "{label}: shape diverged");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{label}: bit divergence at flat index {i}: {x:?} vs {y:?}"
        );
    }
}

/// Bench one layer shape: assert blocked == naive bitwise, then time both.
/// Returns (naive mean s, blocked mean s).
fn kernel_pair(
    r: &BenchRunner,
    label: &str,
    layer: &LayerMeta,
    seed: u64,
) -> (f64, f64) {
    let m = Model::new(layer.name.clone(), vec![layer.clone()]);
    let ws = WeightStore::for_model(&m, seed);
    let store = full_store(Tensor::random(layer.in_h, layer.in_w, layer.in_c, seed ^ 0xABCD));
    let out_r = Region::full(layer.out_h, layer.out_w, layer.out_c);

    let want = naive_region(layer, &ws.layers[0], &store, &out_r);
    let got = compute_region(layer, &ws.layers[0], &store, &out_r);
    assert_bitwise_eq(label, &want, &got.t);

    let naive = r.bench(&format!("naive_{label}"), || {
        naive_region(layer, &ws.layers[0], &store, &out_r).data[0]
    });
    let blocked = r.bench(&format!("blocked_{label}"), || {
        compute_region(layer, &ws.layers[0], &store, &out_r).t.data[0]
    });
    (naive.mean_secs(), blocked.mean_secs())
}

/// Run `warmup + items` inferences through a pipelined edgenet and return
/// (arena allocs, arena reuses, heap allocs over the post-warmup window,
/// post-warmup elapsed seconds, items).
fn serving_run(reuse: bool, warmup: u64, items: u64) -> (u64, u64, u64, f64) {
    let model = zoo::edgenet(32);
    let weights = WeightStore::for_model(&model, 1);
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let cfg = ComputeConfig { reuse_buffers: reuse, ..ComputeConfig::default() };
    let mut pipe = BlockPipeline::start_with(&model, &plan, &weights, 4, 4, 0, cfg);
    let input = Tensor::random(32, 32, 3, 7);
    for _ in 0..warmup {
        pipe.submit(input.clone());
        let _ = pipe.wait_complete();
    }
    let heap0 = heap_allocs();
    let t0 = Instant::now();
    for _ in 0..items {
        pipe.submit(input.clone());
        let _ = pipe.wait_complete();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let heap = heap_allocs() - heap0;
    let (_, stats) = pipe.finish();
    let allocs: u64 = stats.stages.iter().map(|s| s.buf_allocs).sum();
    let reuses: u64 = stats.stages.iter().map(|s| s.buf_reuses).sum();
    (allocs, reuses, heap, elapsed)
}

fn main() {
    let r = BenchRunner::new("kernel_bench");

    // --- kernel speedups (bitwise-checked) ---------------------------------
    let conv56 = LayerMeta::conv("c56", ConvType::Standard, 56, 56, 128, 128, 3, 1, 1);
    let (conv_naive, conv_blocked) = kernel_pair(&r, "conv56x56x128x128", &conv56, 11);

    let pw = LayerMeta::conv("pw", ConvType::Pointwise, 56, 56, 128, 128, 1, 1, 0);
    let (pw_naive, pw_blocked) = kernel_pair(&r, "pointwise56x56x128x128", &pw, 12);

    let dw = LayerMeta::conv("dw", ConvType::Depthwise, 56, 56, 128, 128, 3, 1, 1);
    let (dw_naive, dw_blocked) = kernel_pair(&r, "depthwise56x56x128", &dw, 13);

    let fc = LayerMeta::dense("fc", 128, 512, 512);
    let (fc_naive, fc_blocked) = kernel_pair(&r, "dense128x512x512", &fc, 14);

    // --- tile parallelism --------------------------------------------------
    let m = Model::new("c56", vec![conv56.clone()]);
    let ws = WeightStore::for_model(&m, 11);
    let store = full_store(Tensor::random(56, 56, 128, 21));
    let stores = [&store];
    let tiles = out_tiles(&conv56, Scheme::InH, 8);
    let items: Vec<(usize, Region)> = tiles.iter().map(|t| (0usize, *t)).collect();
    let par_cfg = ComputeConfig { tile_workers: 4, parallel_threshold: 0, ..Default::default() };
    {
        // parallel must be bitwise identical to serial before it is timed
        let mut a0 = TensorArena::new(true);
        let mut a1 = TensorArena::new(true);
        let serial = compute_tile_set(
            &conv56, &ws.layers[0], &stores, &items, &ComputeConfig::serial(), &mut a0,
        );
        let par =
            compute_tile_set(&conv56, &ws.layers[0], &stores, &items, &par_cfg, &mut a1);
        for (s, p) in serial.iter().zip(&par) {
            assert_bitwise_eq("tile_parallel", &s.t, &p.t);
        }
    }
    let mut arena = TensorArena::new(true);
    let serial_s = r
        .bench("tiles_serial/8xInH", || {
            let outs = compute_tile_set(
                &conv56, &ws.layers[0], &stores, &items, &ComputeConfig::serial(), &mut arena,
            );
            let v = outs[0].t.data[0];
            for o in outs {
                arena.give(o.t);
            }
            black_box(v)
        })
        .mean_secs();
    let par_s = r
        .bench("tiles_parallel/8xInH/4w", || {
            let outs =
                compute_tile_set(&conv56, &ws.layers[0], &stores, &items, &par_cfg, &mut arena);
            let v = outs[0].t.data[0];
            for o in outs {
                arena.give(o.t);
            }
            black_box(v)
        })
        .mean_secs();

    // --- allocation regression guard --------------------------------------
    // Steady-state arena allocations for `items` inferences = (warmup+items
    // run) − (warmup-only run); the arena take sequence is a pure function
    // of the item count, so the difference isolates the post-warmup window.
    let (warmup, items_n) = (4, 48);
    let (on_base, _, _, _) = serving_run(true, warmup, 0);
    let (on_full, on_reuses, on_heap, on_elapsed) = serving_run(true, warmup, items_n);
    let (off_base, _, _, _) = serving_run(false, warmup, 0);
    let (off_full, _, off_heap, _) = serving_run(false, warmup, items_n);
    let on_steady = on_full.saturating_sub(on_base);
    let off_steady = off_full.saturating_sub(off_base);
    let arena_ratio = off_steady as f64 / on_steady.max(1) as f64;
    let heap_ratio = off_heap as f64 / on_heap.max(1) as f64;
    let req_s = items_n as f64 / on_elapsed;
    println!(
        "serving arena allocs/{items_n} items: reuse={on_steady} (reuses={on_reuses}) \
         no-reuse={off_steady} ratio={arena_ratio:.1} | heap {on_heap} vs {off_heap} \
         ({heap_ratio:.2}x) | {req_s:.1} req/s"
    );

    let guard: f64 = std::env::var("FLEXPIE_ALLOC_GUARD")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10.0);
    assert!(
        arena_ratio >= guard,
        "allocation regression: steady-state arena alloc ratio {arena_ratio:.1} \
         < guard {guard} (reuse on: {on_steady}, reuse off: {off_steady})"
    );

    emit_result(vec![
        ("bench", Json::Str("kernel_bench".into())),
        ("conv56_naive_s", Json::Num(conv_naive)),
        ("conv56_blocked_s", Json::Num(conv_blocked)),
        ("conv56_speedup", Json::Num(conv_naive / conv_blocked)),
        ("pointwise_speedup", Json::Num(pw_naive / pw_blocked)),
        ("depthwise_speedup", Json::Num(dw_naive / dw_blocked)),
        ("dense_speedup", Json::Num(fc_naive / fc_blocked)),
        ("tile_parallel_speedup", Json::Num(serial_s / par_s)),
        ("tile_workers", Json::Num(par_cfg.tile_workers as f64)),
        ("serve_items", Json::Num(items_n as f64)),
        ("serve_arena_allocs_reuse", Json::Num(on_steady as f64)),
        ("serve_arena_allocs_noreuse", Json::Num(off_steady as f64)),
        ("serve_arena_alloc_ratio", Json::Num(arena_ratio)),
        ("serve_heap_alloc_ratio", Json::Num(heap_ratio)),
        ("serve_req_s", Json::Num(req_s)),
        ("alloc_guard", Json::Num(guard)),
    ]);
}
