//! Fig 7 reproduction: 4-node testbed — MobileNet / ResNet-18 / ResNet-101 /
//! BERT × {OutC, InH/InW, 2D-grid, Layerwise, Fused-layer, FlexPie} ×
//! {5, 1, 0.5} Gb/s × {Ring, PS}.
//!
//! Paper shape to check: 2D-grid is the best fixed scheme, OutC the worst;
//! layerwise and fused beat fixed; FlexPie wins every row (1.10–2.21×);
//! BERT rows are nearly flat.
//!
//! Set FLEXPIE_BENCH_FAST=1 to truncate models for smoke runs; pass
//! `--cost analytic` semantics via FLEXPIE_BENCH_COST=analytic.

use flexpie::bench::{fig7_9, fig7_9_tables, BenchOpts, CostKind};

fn opts() -> BenchOpts {
    let mut o = BenchOpts::default();
    if std::env::var("FLEXPIE_BENCH_COST").as_deref() == Ok("analytic") {
        o.cost = CostKind::Analytic;
    }
    o
}

fn main() {
    let opts = opts();
    let t0 = std::time::Instant::now();
    let cells = fig7_9(4, &opts);
    for (title, t) in fig7_9_tables(&cells) {
        println!("\n== Fig 7 [{title}] ==");
        t.print();
    }
    println!("\n({} cells in {:.1}s)", cells.len(), t0.elapsed().as_secs_f64());
}
