//! Forecast-warmed vs reactive adaptation — what pre-warming the coming
//! regime buys the serving path.
//!
//! Two frontends ride the same deterministic world: a staircase bandwidth
//! descent with a node death scripted to land **at the same boundary as a
//! condition-cell shift** — the compound case PR 2's reactive speculation
//! cannot cover (its n−1 cells are warm for the *old* bandwidth only, so
//! the failover rendezvous runs a cold search).
//!
//! * **reactive** — trace-driven, forecasting off: the PR 1–4 behavior.
//! * **forecast** — the same conditions observed through the telemetry
//!   path (probes → store → forecaster), with the background planner
//!   pre-warming the projected cell and its n−1 set at the *forecast*
//!   bandwidth.
//!
//! Single-line `RESULT` JSON carries the failover-boundary stall of both
//! paths (max boundary stall — the rendezvous is the only stall either
//! path has), the warm-up ratio, and the forecast hit/miss/horizon-error
//! counters.
//!
//! ```bash
//! cargo bench --bench forecast_warmup
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench forecast_warmup   # CI smoke
//! ```

use flexpie::elastic::{ConditionTrace, ElasticConfig, ElasticFrontend};
use flexpie::metrics::{AdaptationMetrics, Summary};
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::telemetry::{ForecastConfig, TelemetryConfig, TelemetrySource};
use flexpie::util::bench::emit_result;
use flexpie::util::json::Json;

/// Staircase descent: 5% of baseline bandwidth per virtual second, from
/// t = 10 down to 75% at t = 15 — quantized-cell shifts at known times.
fn staircase(nodes: usize) -> ConditionTrace {
    ConditionTrace::stable(nodes)
        .with_bandwidth_dip(11.0, 12.0, 0.95)
        .with_bandwidth_dip(12.0, 13.0, 0.90)
        .with_bandwidth_dip(13.0, 14.0, 0.85)
        .with_bandwidth_dip(14.0, 15.0, 0.80)
        .with_bandwidth_dip(15.0, f64::INFINITY, 0.75)
}

const BOUNDARY_DT: f64 = 0.5;
const BOUNDARIES: usize = 41; // t = 0 .. 20

/// Drive one frontend across the schedule, quiescing the planner each
/// boundary so cache warmth — not thread scheduling — is the only variable
/// between the two paths.
fn drive(mut fe: ElasticFrontend) -> (AdaptationMetrics, Summary, usize) {
    let mut min_nodes = usize::MAX;
    for k in 0..BOUNDARIES {
        let d = fe.acquire(k as f64 * BOUNDARY_DT);
        min_nodes = min_nodes.min(d.nodes);
        fe.quiesce();
    }
    let (m, stalls) = fe.finish();
    (m, stalls, min_nodes)
}

fn main() {
    // FLEXPIE_BENCH_FAST=1 shrinks the planned model (the drive schedule is
    // model-independent — the scenario depends only on condition buckets),
    // keeping the CI smoke cheap while preserving the warm-vs-cold contrast.
    let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
    let model = zoo::mobilenet_v1(224, 1000).truncated(if fast { 6 } else { 12 });
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    // The node dies inside (13.5, 14.0]: the t = 14.0 boundary sees the
    // death AND the 0.80 window — a bandwidth bucket no reactive n−1
    // speculation has covered (its cells are warm for the old bucket), so
    // the reactive rendezvous must run a cold search. The measured path's
    // estimate lags half a boundary, so its failover lands in the covered
    // bucket and its own shift to the new bucket was forecast-prewarmed.
    let world = staircase(4).with_outage(2, 13.75, f64::INFINITY);

    // --- reactive: trace-driven, no forecasting ----------------------------
    let reactive_fe = ElasticFrontend::start(
        model.clone(),
        base.clone(),
        world.clone(),
        ElasticConfig { cache_capacity: 64, ..ElasticConfig::default() },
    );
    let (reactive_m, reactive_stalls, reactive_min) = drive(reactive_fe);
    println!("reactive:  {reactive_m}");
    println!("reactive boundary stalls: {reactive_stalls}");

    // --- forecast: measured telemetry + pre-warming ------------------------
    let source = TelemetrySource::new(world, &base, TelemetryConfig::default());
    let forecast_fe = ElasticFrontend::start_with_source(
        model.clone(),
        base,
        Box::new(source),
        ElasticConfig {
            cache_capacity: 64,
            forecast: Some(ForecastConfig::default()),
            ..ElasticConfig::default()
        },
    );
    let (forecast_m, forecast_stalls, forecast_min) = drive(forecast_fe);
    println!("forecast:  {forecast_m}");
    println!("forecast boundary stalls: {forecast_stalls}");

    assert_eq!(reactive_min, 3, "reactive path never saw the failover");
    assert_eq!(forecast_min, 3, "measured path never saw the failover");
    assert_eq!(reactive_m.inline_replans, 0);
    assert_eq!(forecast_m.inline_replans, 0);

    // the only stall either path has is the failover rendezvous: reactive
    // pays a cold search there, forecast-warmed pays a cache lookup
    let reactive_us = reactive_stalls.max.as_secs_f64() * 1e6;
    let forecast_us = forecast_stalls.max.as_secs_f64() * 1e6;
    emit_result(vec![
        ("bench", Json::Str("forecast_warmup".into())),
        ("model", Json::Str(model.name.clone())),
        ("boundaries", Json::Num(BOUNDARIES as f64)),
        ("reactive_failover_stall_us", Json::Num(reactive_us)),
        ("forecast_failover_stall_us", Json::Num(forecast_us)),
        ("warmup_speedup", Json::Num(reactive_us / forecast_us.max(1e-3))),
        ("reactive_replans", Json::Num(reactive_m.replans as f64)),
        ("forecast_replans", Json::Num(forecast_m.replans as f64)),
        ("reactive_speculative_hits", Json::Num(reactive_m.speculative_hits as f64)),
        ("forecast_speculative_hits", Json::Num(forecast_m.speculative_hits as f64)),
        ("forecasts", Json::Num(forecast_m.forecasts as f64)),
        ("forecast_plans", Json::Num(forecast_m.forecast_plans as f64)),
        ("forecast_hits", Json::Num(forecast_m.forecast_hits as f64)),
        ("forecast_misses", Json::Num(forecast_m.forecast_misses as f64)),
        ("forecast_mean_bucket_err", Json::Num(forecast_m.forecast_mean_bucket_err())),
        ("stall_p99_reactive_us", Json::Num(reactive_stalls.p99.as_secs_f64() * 1e6)),
        ("stall_p99_forecast_us", Json::Num(forecast_stalls.p99.as_secs_f64() * 1e6)),
    ]);
}
