//! Pipelined vs lockstep serving throughput — the acceptance bench for the
//! block-pipelined executor.
//!
//! Workload: a balanced 6-conv chain served all-T on a 2-node cluster, so
//! the plan has 6 equal pipeline stages. The lockstep executor runs one
//! inference at a time (and underuses the host's cores at `nodes = 2`);
//! the pipeline keeps every block busy on a different in-flight inference,
//! so measured requests/sec should exceed lockstep by well over the 1.5×
//! acceptance bar on any multi-core host.
//!
//! The single-line `RESULT` JSON carries: measured lockstep vs pipelined
//! requests/sec and their ratio, per-stage occupancy and the measured
//! bottleneck stage, the virtual-clock stage decomposition of the served
//! plan, and both planner objectives' metrics on this testbed
//! (latency-objective total + its bottleneck, throughput-objective
//! bottleneck).
//!
//! ```bash
//! cargo bench --bench pipeline_throughput
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench pipeline_throughput   # CI smoke
//! ```

use std::time::Instant;

use flexpie::cluster::pipeline::run_pipelined;
use flexpie::cluster::run_distributed;
use flexpie::compute::{Tensor, WeightStore};
use flexpie::config::PipelineExperiment;
use flexpie::cost::{CostSource, Objective};
use flexpie::model::zoo;
use flexpie::partition::{Plan, Scheme};
use flexpie::planner::exhaustive::{bottleneck_cost, stage_costs};
use flexpie::planner::{Dpp, DppConfig};
use flexpie::util::bench::{black_box, emit_result};
use flexpie::util::json::Json;

fn main() {
    let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
    let exp = PipelineExperiment {
        model: "tiny_chain6".into(),
        nodes: 2,
        pipeline_depth: 8,
        requests: if fast { 16 } else { 48 },
        ..Default::default()
    };
    let model = zoo::tiny_chain(6, 32, 24);
    let tb = exp.testbed();
    // the balanced ≥3-block plan the acceptance criterion names: uniform
    // scheme, every layer T → one stage per layer
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let ws = WeightStore::for_model(&model, 17);
    let l0 = &model.layers[0];
    let inputs: Vec<Tensor> = (0..exp.requests)
        .map(|i| Tensor::random(l0.in_h, l0.in_w, l0.in_c, i as u64))
        .collect();

    // warm both paths once (page in weights, fault in code)
    black_box(run_distributed(&model, &plan, &ws, &inputs[0], exp.nodes));
    black_box(run_pipelined(&model, &plan, &ws, &inputs[..1], exp.nodes, 1));

    let t0 = Instant::now();
    for input in &inputs {
        black_box(run_distributed(&model, &plan, &ws, input, exp.nodes).output);
    }
    let lockstep_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let (outs, pstats) =
        run_pipelined(&model, &plan, &ws, &inputs, exp.nodes, exp.pipeline_depth);
    let pipelined_secs = t0.elapsed().as_secs_f64();
    assert_eq!(outs.len(), inputs.len(), "pipeline lost inferences");

    let lockstep_rps = exp.requests as f64 / lockstep_secs.max(1e-12);
    let pipelined_rps = exp.requests as f64 / pipelined_secs.max(1e-12);
    let speedup = pipelined_rps / lockstep_rps.max(1e-12);
    let occupancy = pstats.occupancy();
    println!(
        "lockstep {lockstep_rps:.1} req/s | pipelined {pipelined_rps:.1} req/s \
         ({speedup:.2}x) over {} stages, bottleneck s{}",
        pstats.stages.len(),
        pstats.bottleneck_stage()
    );

    // virtual-clock decomposition + both planner objectives on this testbed
    let cost = CostSource::analytic(&tb);
    let stage_ms: Vec<f64> = stage_costs(&model, &plan, &cost)
        .into_iter()
        .map(|s| s * 1e3)
        .collect();
    let lat_plan = Dpp::new(&model, &cost).plan();
    let thr_plan = Dpp::with_config(
        &model,
        &cost,
        DppConfig { objective: Objective::Throughput, ..Default::default() },
    )
    .plan();
    let lat_bottleneck = bottleneck_cost(&model, &lat_plan, &cost);
    println!(
        "objectives: latency plan {:.3} ms total (bottleneck {:.3} ms) | \
         throughput plan bottleneck {:.3} ms",
        lat_plan.est_cost * 1e3,
        lat_bottleneck * 1e3,
        thr_plan.est_cost * 1e3
    );

    emit_result(vec![
        ("bench", Json::Str("pipeline_throughput".into())),
        ("experiment", exp.to_json()),
        ("model", Json::Str(model.name.clone())),
        ("blocks", Json::Num(plan.blocks().len() as f64)),
        ("requests", Json::Num(exp.requests as f64)),
        ("lockstep_rps", Json::Num(lockstep_rps)),
        ("pipelined_rps", Json::Num(pipelined_rps)),
        ("pipelined_speedup", Json::Num(speedup)),
        ("stage_occupancy", Json::num_arr(&occupancy)),
        ("bottleneck_stage", Json::Num(pstats.bottleneck_stage() as f64)),
        ("stage_times_ms", Json::num_arr(&stage_ms)),
        ("latency_objective_total_ms", Json::Num(lat_plan.est_cost * 1e3)),
        ("latency_objective_bottleneck_ms", Json::Num(lat_bottleneck * 1e3)),
        ("throughput_objective_bottleneck_ms", Json::Num(thr_plan.est_cost * 1e3)),
    ]);
}
