//! Elastic adaptation latency — what a replan costs on the serving path.
//!
//! The numbers that matter for online adaptation, all in the single-line
//! JSON summary (prefixed `RESULT `) for trajectory tracking across PRs:
//!
//! * the cold replan (serial unmemoized — the PR 1 baseline — vs the
//!   wavefront-parallel search),
//! * replan *throughput* over a realistic workload (the speculative n−1
//!   failover set × a bandwidth sweep) on a 4-worker pool over a prewarmed
//!   query memo, vs planning the same cells serially and uncached,
//! * the pure-bandwidth-drift replan's memo counters (sync misses must be
//!   zero: drift is served by analytic re-pricing of cached geometry),
//! * the warm plan-cache hit and the sync controller's `on_batch` check,
//! * p50/p99 batch-boundary stall of a real server on the background
//!   replanner path, across a scripted bandwidth dip *and* a node outage.
//!
//! ```bash
//! cargo bench --bench elastic_replan            # full
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench elastic_replan   # CI smoke
//! ```

use std::sync::Arc;
use std::time::Duration;

use flexpie::compute::{Tensor, WeightStore};
use flexpie::cost::MemoStore;
use flexpie::elastic::{CacheKey, ConditionTrace, ElasticConfig, ElasticController, PlanCache};
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::{
    plan_batch, plan_for_testbed, plan_for_testbed_opts, prewarm_memo, PlannerOpts,
};
use flexpie::serve::{ServeConfig, Server};
use flexpie::util::bench::{black_box, emit_result, BenchRunner};
use flexpie::util::json::Json;

fn main() {
    let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
    let r = BenchRunner::new("elastic_replan");
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let model = zoo::mobilenet_v1(224, 1000).truncated(12);
    let workers = 4usize;

    // --- cold replan: serial unmemoized (PR 1 baseline) vs parallel --------
    let serial_opts = PlannerOpts::serial();
    let cold = r.bench("cold_replan_serial/mobilenet12_4node", || {
        plan_for_testbed_opts(black_box(&model), black_box(&base), &serial_opts)
    });
    let par_opts = PlannerOpts { workers, memo: None };
    let cold_par = r.bench("cold_replan_parallel4/mobilenet12_4node", || {
        plan_for_testbed_opts(black_box(&model), black_box(&base), &par_opts)
    });

    // --- replan throughput: the workload a regime shift hands the planner --
    // (full-cluster plan + an n−1 failover cell, across a bandwidth sweep)
    let mut cells: Vec<Testbed> = Vec::new();
    for factor in [1.0, 0.85, 0.7, 0.55, 0.4, 0.25] {
        let tb = base.with_bandwidth_factor(factor);
        cells.push(tb.clone());
        cells.push(tb.subset(&[true, true, false, true]));
    }
    let workload_serial = r.bench("replan_workload/serial_unmemoized", || {
        for tb in &cells {
            black_box(plan_for_testbed_opts(&model, tb, &serial_opts));
        }
    });
    let store = MemoStore::shared();
    prewarm_memo(&model, &base, &store);
    let pool_opts = PlannerOpts { workers, memo: Some(store.clone()) };
    let workload_pool = r.bench("replan_workload/pool4_memoized", || {
        black_box(plan_batch(&model, &cells, &pool_opts));
    });
    let throughput_speedup =
        workload_serial.mean_secs() / workload_pool.mean_secs().max(1e-12);

    // --- pure-bandwidth-drift replan: zero inner sync queries ---------------
    let drift = base.with_bandwidth_factor(0.33);
    let (_, drift_stats) = plan_for_testbed_opts(
        &model,
        &drift,
        &PlannerOpts { workers, memo: Some(store.clone()) },
    );
    let drift_memo = drift_stats.memo;

    // --- warm path: plan-cache hit ------------------------------------------
    let trace = ConditionTrace::stable(4);
    let snap = trace.sample(0.0);
    let key = CacheKey::new(&model.name, snap.quantize());
    let mut cache = PlanCache::new(8);
    cache.put(key.clone(), Arc::new(plan_for_testbed(&model, &base)));
    let hit = r.bench("cache_hit/get", || cache.get(black_box(&key)));

    // --- steady state: per-batch monitor check (sync controller path) -------
    let mut ctl = ElasticController::new(
        model.clone(),
        base.clone(),
        ConditionTrace::stable(4),
        ElasticConfig::default(),
    );
    let mut t = 0.0f64;
    let monitor = r.bench("on_batch/stable_fast_path", || {
        t += 1e-3;
        ctl.on_batch(t)
    });

    // --- batch-boundary stall on the background-replanner serving path ------
    let serve_model = zoo::edgenet(16);
    let sbase = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let item = {
        let p = plan_for_testbed(&serve_model, &sbase);
        flexpie::engine::evaluate(&serve_model, &p, &sbase).total
    };
    // mid-stream bandwidth dip and a scripted outage: boundaries must stay
    // wait-free through both
    let strace = ConditionTrace::stable(4)
        .with_bandwidth_dip(6.5 * item, 14.5 * item, 0.1)
        .with_outage(2, 22.5 * item, 30.5 * item);
    let server = Server::start_elastic(
        serve_model.clone(),
        WeightStore::for_model(&serve_model, 7),
        sbase,
        strace,
        ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            ..ServeConfig::default()
        },
        ElasticConfig::default(),
    );
    let l0 = &serve_model.layers[0];
    let n_requests: u64 = if fast { 24 } else { 48 };
    for i in 0..n_requests {
        server
            .infer(Tensor::random(l0.in_h, l0.in_w, l0.in_c, i))
            .expect("request lost");
    }
    let stats = server.shutdown();
    let stall = stats.boundary_stall.expect("elastic path reports boundary stalls");
    let adapt = stats.adaptation.expect("elastic path reports adaptation");
    println!("serving adaptation: {adapt}");
    println!("batch-boundary stall: {stall}");

    // --- single-line JSON summary -------------------------------------------
    emit_result(vec![
        ("bench", Json::Str("elastic_replan".into())),
        ("model", Json::Str(model.name.clone())),
        ("nodes", Json::Num(4.0)),
        ("replan_workers", Json::Num(workers as f64)),
        ("cold_replan_ms", Json::Num(cold.mean_secs() * 1e3)),
        ("cold_replan_parallel_ms", Json::Num(cold_par.mean_secs() * 1e3)),
        (
            "parallel_search_speedup",
            Json::Num(cold.mean_secs() / cold_par.mean_secs().max(1e-12)),
        ),
        ("replan_workload_cells", Json::Num(cells.len() as f64)),
        ("replan_throughput_speedup", Json::Num(throughput_speedup)),
        ("drift_sync_misses", Json::Num(drift_memo.sync_misses as f64)),
        ("drift_sync_rescales", Json::Num(drift_memo.sync_rescales as f64)),
        ("memo_sync_warm_rate", Json::Num(drift_memo.sync_warm_rate())),
        ("memo_compute_hit_rate", Json::Num(drift_memo.compute_hit_rate())),
        ("cache_hit_us", Json::Num(hit.mean_secs() * 1e6)),
        ("on_batch_us", Json::Num(monitor.mean_secs() * 1e6)),
        (
            "replan_speedup_vs_cache",
            Json::Num(cold.mean_secs() / hit.mean_secs().max(1e-12)),
        ),
        ("stall_p50_us", Json::Num(stall.p50.as_secs_f64() * 1e6)),
        ("stall_p99_us", Json::Num(stall.p99.as_secs_f64() * 1e6)),
        ("stall_max_us", Json::Num(stall.max.as_secs_f64() * 1e6)),
        ("speculative_plans", Json::Num(adapt.speculative_plans as f64)),
        ("speculative_hits", Json::Num(adapt.speculative_hits as f64)),
        ("inline_replans", Json::Num(adapt.inline_replans as f64)),
    ]);
}
