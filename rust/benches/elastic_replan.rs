//! Elastic adaptation latency — what a replan costs on the serving path.
//!
//! Three numbers matter for online adaptation: the cold replan (full DPP
//! search for an unseen condition cell), the warm plan-cache hit, and the
//! steady-state `on_batch` monitor check (re-pricing the active plan). The
//! bench measures each in isolation and emits a single-line JSON summary
//! (prefixed `RESULT `) for trajectory tracking across PRs.
//!
//! ```bash
//! cargo bench --bench elastic_replan            # full
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench elastic_replan   # CI smoke
//! ```

use std::sync::Arc;

use flexpie::elastic::{CacheKey, ConditionTrace, ElasticConfig, ElasticController, PlanCache};
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::plan_for_testbed;
use flexpie::util::bench::{black_box, BenchRunner};
use flexpie::util::json::Json;

fn main() {
    let r = BenchRunner::new("elastic_replan");
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let model = zoo::mobilenet_v1(224, 1000).truncated(12);

    // --- cold replan: full DPP for an unseen condition cell ----------------
    let cold = r.bench("cold_replan/mobilenet12_4node", || {
        plan_for_testbed(black_box(&model), black_box(&base))
    });

    // --- warm path: plan-cache hit ------------------------------------------
    let trace = ConditionTrace::stable(4);
    let snap = trace.sample(0.0);
    let key = CacheKey::new(&model.name, snap.quantize());
    let mut cache = PlanCache::new(8);
    cache.put(key.clone(), Arc::new(plan_for_testbed(&model, &base)));
    let hit = r.bench("cache_hit/get", || cache.get(black_box(&key)));

    // --- steady state: per-batch monitor check (no swap) --------------------
    let mut ctl = ElasticController::new(
        model.clone(),
        base.clone(),
        ConditionTrace::stable(4),
        ElasticConfig::default(),
    );
    let mut t = 0.0f64;
    let monitor = r.bench("on_batch/stable_fast_path", || {
        t += 1e-3;
        ctl.on_batch(t)
    });

    // --- single-line JSON summary -------------------------------------------
    let summary = Json::obj(vec![
        ("bench", Json::Str("elastic_replan".into())),
        ("model", Json::Str(model.name.clone())),
        ("nodes", Json::Num(4.0)),
        ("cold_replan_ms", Json::Num(cold.mean_secs() * 1e3)),
        ("cache_hit_us", Json::Num(hit.mean_secs() * 1e6)),
        ("on_batch_us", Json::Num(monitor.mean_secs() * 1e6)),
        (
            "replan_speedup_vs_cache",
            Json::Num(cold.mean_secs() / hit.mean_secs().max(1e-12)),
        ),
    ]);
    println!("RESULT {}", summary.to_string());
}
