//! Fig 9 reproduction: the Fig 7 grid on the 3-node testbed.
//!
//! Paper shape to check: 2D-grid becomes the *worst* fixed scheme (one node
//! carries 2× the work on a 2×2 grid over 3 devices); FlexPie still wins
//! every row (1.08–2.39×).

use flexpie::bench::{fig7_9, fig7_9_tables, BenchOpts, CostKind};

fn main() {
    let mut opts = BenchOpts::default();
    if std::env::var("FLEXPIE_BENCH_COST").as_deref() == Ok("analytic") {
        opts.cost = CostKind::Analytic;
    }
    let t0 = std::time::Instant::now();
    let cells = fig7_9(3, &opts);
    for (title, t) in fig7_9_tables(&cells) {
        println!("\n== Fig 9 [{title}] ==");
        t.print();
    }
    println!("\n({} cells in {:.1}s)", cells.len(), t0.elapsed().as_secs_f64());
}
