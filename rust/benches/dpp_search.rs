//! DPP search-time cost (paper §4 Metrics) and the pruning ablation: plan
//! wall-clock + estimator-call counts per benchmark model, with and without
//! the dynamic-threshold pruning, against the naive combinatorial space
//! size DPP avoids.

use flexpie::bench::{search_time, search_time_table, BenchOpts, CostKind};
use flexpie::cost::CostSource;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::Dpp;
use flexpie::util::bench::BenchRunner;

fn main() {
    let opts = BenchOpts { cost: CostKind::Analytic, ..Default::default() };
    println!("== DPP search time (analytic CE) ==");
    search_time_table(&search_time(&opts)).print();

    // steady-state planning latency (what a deployment pays per testbed
    // change), measured properly with warmup
    let r = BenchRunner::new("dpp");
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
    let cost = CostSource::analytic(&tb);
    for (name, model) in [
        ("mobilenet", zoo::mobilenet_v1(224, 1000)),
        ("resnet18", zoo::resnet18(224, 1000)),
        ("resnet101", zoo::resnet101(224, 1000)),
        ("bert", zoo::bert_base(128)),
    ] {
        let dpp = Dpp::new(&model, &cost);
        r.bench(&format!("plan/{name}"), || dpp.plan().est_cost);
    }
}
