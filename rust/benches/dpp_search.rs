//! DPP search-time cost (paper §4 Metrics) and the pruning ablation: plan
//! wall-clock + estimator-call counts per benchmark model, with and without
//! the dynamic-threshold pruning, against the naive combinatorial space
//! size DPP avoids. Also tracks the planner's speed knobs across PRs —
//! serial vs wavefront-parallel search and the memoized cost source — via a
//! single-line `RESULT` JSON summary (all knobs are cost-transparent: the
//! plans are bit-identical, only wall-clock differs).

use flexpie::bench::{search_time, search_time_table, BenchOpts, CostKind};
use flexpie::cost::{CostSource, MemoStore};
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::{prewarm_memo, Dpp, DppConfig};
use flexpie::util::bench::{emit_result, BenchRunner};
use flexpie::util::json::Json;

fn main() {
    let opts = BenchOpts { cost: CostKind::Analytic, ..Default::default() };
    println!("== DPP search time (analytic CE) ==");
    search_time_table(&search_time(&opts)).print();

    // steady-state planning latency (what a deployment pays per testbed
    // change), measured properly with warmup
    let r = BenchRunner::new("dpp");
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
    let cost = CostSource::analytic(&tb);
    for (name, model) in [
        ("mobilenet", zoo::mobilenet_v1(224, 1000)),
        ("resnet18", zoo::resnet18(224, 1000)),
        ("resnet101", zoo::resnet101(224, 1000)),
        ("bert", zoo::bert_base(128)),
    ] {
        let dpp = Dpp::new(&model, &cost);
        r.bench(&format!("plan/{name}"), || dpp.plan().est_cost);
    }

    // serial vs parallel vs memoized on one reference model
    let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
    let model = if fast {
        zoo::mobilenet_v1(224, 1000).truncated(12)
    } else {
        zoo::mobilenet_v1(224, 1000)
    };
    let workers = 4usize;
    let serial_cfg = DppConfig { workers: 1, ..Default::default() };
    let par_cfg = DppConfig { workers, ..Default::default() };
    let serial = r.bench(&format!("plan_serial/{}", model.name), || {
        Dpp::with_config(&model, &cost, serial_cfg.clone()).plan().est_cost
    });
    let parallel = r.bench(&format!("plan_parallel{workers}/{}", model.name), || {
        Dpp::with_config(&model, &cost, par_cfg.clone()).plan().est_cost
    });

    // memoized source, prewarmed with the full query universe: the warm
    // replan path the elastic layer runs after its first search
    let store = MemoStore::shared();
    prewarm_memo(&model, &tb, &store);
    let memo_cost = CostSource::analytic(&tb).memoized(&store);
    let warm = r.bench(&format!("plan_parallel{workers}_memo_warm/{}", model.name), || {
        Dpp::with_config(&model, &memo_cost, par_cfg.clone()).plan().est_cost
    });
    let (_, mstats) = Dpp::with_config(&model, &memo_cost, par_cfg.clone()).plan_with_stats();

    emit_result(vec![
        ("bench", Json::Str("dpp_search".into())),
        ("model", Json::Str(model.name.clone())),
        ("nodes", Json::Num(4.0)),
        ("workers", Json::Num(workers as f64)),
        ("serial_ms", Json::Num(serial.mean_secs() * 1e3)),
        ("parallel_ms", Json::Num(parallel.mean_secs() * 1e3)),
        (
            "parallel_speedup",
            Json::Num(serial.mean_secs() / parallel.mean_secs().max(1e-12)),
        ),
        ("parallel_memo_warm_ms", Json::Num(warm.mean_secs() * 1e3)),
        (
            "memo_warm_speedup",
            Json::Num(serial.mean_secs() / warm.mean_secs().max(1e-12)),
        ),
        ("memo_compute_hit_rate", Json::Num(mstats.memo.compute_hit_rate())),
        ("memo_sync_warm_rate", Json::Num(mstats.memo.sync_warm_rate())),
        ("memo_sync_misses", Json::Num(mstats.memo.sync_misses as f64)),
    ]);
}
