//! Open-loop load harness: the full suite ladder (A1–A4 deterministic,
//! B1–B2 Poisson with chaos), multi-process agents, merged tail-latency
//! percentiles and SLO-violation fractions.
//!
//! One `RESULT` line per suite (CI assembles them into `BENCH_pr9.json`);
//! the assembled JSON is also written to `bench_results/load_harness.json`.
//!
//! ```bash
//! cargo bench --bench load_harness
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench load_harness   # CI smoke
//! ```

use flexpie::bench::harness::{self, HarnessOpts};
use flexpie::util::bench::{emit_result_json, Table};

fn main() {
    let opts = HarnessOpts {
        load_bin: env!("CARGO_BIN_EXE_flexpie-load").to_string(),
        node_bin: env!("CARGO_BIN_EXE_flexpie-node").to_string(),
        fast: std::env::var("FLEXPIE_BENCH_FAST").is_ok(),
        // every run leaves trace/metrics artifacts next to the trajectory
        // JSON — `tools/check_trace.py` gates them in CI
        artifact_dir: Some("bench_results".to_string()),
    };
    let mut reports = Vec::new();
    for spec in harness::suites(opts.fast) {
        eprintln!("[load_harness] running suite {}", spec.name);
        match harness::run_suite(&spec, &opts) {
            Ok(r) => {
                emit_result_json(&r.to_json());
                reports.push(r);
            }
            Err(e) => {
                eprintln!("load_harness: {e}");
                std::process::exit(1);
            }
        }
    }

    let mut t = Table::new([
        "suite", "mode", "sent", "ok", "shed", "p50", "p99", "p99.9", "q-p99", "svc-p99",
        "wire-p99", "goodput", "slo-viol",
    ]);
    for r in &reports {
        t.row([
            r.suite.clone(),
            r.mode.clone(),
            r.sent.to_string(),
            r.ok.to_string(),
            r.shed.to_string(),
            format!("{:.0} µs", r.p50_us),
            format!("{:.0} µs", r.p99_us),
            format!("{:.0} µs", r.p999_us),
            format!("{:.0} µs", r.queue_hist.percentile(0.99) as f64 / 1e3),
            format!("{:.0} µs", r.service_hist.percentile(0.99) as f64 / 1e3),
            format!("{:.0} µs", r.wire_hist.percentile(0.99) as f64 / 1e3),
            format!("{:.1} rps", r.goodput_rps),
            format!("{:.3}", r.slo_violation_frac),
        ]);
    }
    t.print();

    let assembled = harness::assemble(&reports);
    let out = std::path::Path::new("bench_results/load_harness.json");
    if let Err(e) = assembled.save(out) {
        eprintln!("[load_harness] warning: could not save {}: {e}", out.display());
    }
}
