//! Fig 2 reproduction: MobileNet L2/L5/L13 micro-bench across partition
//! schemes on 4-node and 3-node testbeds (5 Gb/s ring), plus wall-clock
//! timing of the underlying evaluation path.
//!
//! Paper shape to check: L2/L5 prefer spatial partitions (InH/2D-grid),
//! L13 prefers OutC; the winner flips between the 4-node and 3-node rows.

use flexpie::bench::{fig2, fig2_table, BenchOpts, CostKind};
use flexpie::util::bench::BenchRunner;

fn main() {
    let opts = BenchOpts { cost: CostKind::Analytic, ..Default::default() };
    println!("== Fig 2: micro-bench (per-layer inference time) ==");
    let rows = fig2(&opts);
    fig2_table(&rows).print();

    // wall-clock of the generator itself (regression guard)
    let r = BenchRunner::new("fig2");
    r.bench("generate_all_cells", || fig2(&opts).len());
}
