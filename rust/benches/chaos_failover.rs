//! Failover machinery cost — what losing a node (the leader included)
//! costs the serving path, plus a full audited chaos drill.
//!
//! Single-line `RESULT` JSON carries:
//!
//! * steady-state failover decide time at a batch boundary, leader-loss vs
//!   worker-loss, both served from the warm plan cache,
//! * wall-clock of aborting vs draining a pipeline generation with work in
//!   flight (the leader-death vs worker-death boundary),
//! * a full seeded chaos drill through the pipelined elastic server:
//!   request throughput and the audited counters (lost must be 0).
//!
//! ```bash
//! cargo bench --bench chaos_failover
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench chaos_failover   # CI smoke
//! ```

use std::time::{Duration, Instant};

use flexpie::cluster::pipeline::BlockPipeline;
use flexpie::compute::{Tensor, WeightStore};
use flexpie::config::ChaosExperiment;
use flexpie::elastic::{run_chaos, ConditionTrace, ElasticConfig, ElasticController};
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::plan_for_testbed;
use flexpie::serve::ServeConfig;
use flexpie::util::bench::{black_box, emit_result, BenchRunner};
use flexpie::util::json::Json;

fn main() {
    let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
    let r = BenchRunner::new("chaos_failover");
    let model = zoo::edgenet(16);
    let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let plan = plan_for_testbed(&model, &base);
    let c4 = engine::evaluate(&model, &plan, &base).total;

    // --- steady-state failover decide: leader vs worker loss --------------
    // Alternate healthy/dead snapshots so every on_batch is a node-set
    // failover served from the warm plan cache — the boundary cost a real
    // outage pays once both cells have been planned.
    let ltrace = ConditionTrace::stable(4).with_outage(0, 1.0, 2.0);
    let mut lctl =
        ElasticController::new(model.clone(), base.clone(), ltrace, ElasticConfig::default());
    lctl.on_batch(0.5);
    lctl.on_batch(1.5); // cold 3-node plan
    lctl.on_batch(0.5); // warm swap back
    let mut flip = false;
    let leader_failover = r.bench("failover_decide/leader_warm", || {
        flip = !flip;
        lctl.on_batch(if flip { 1.5 } else { 0.5 })
    });

    let wtrace = ConditionTrace::stable(4).with_outage(2, 1.0, 2.0);
    let mut wctl =
        ElasticController::new(model.clone(), base.clone(), wtrace, ElasticConfig::default());
    wctl.on_batch(0.5);
    wctl.on_batch(1.5);
    wctl.on_batch(0.5);
    let mut wflip = false;
    let worker_failover = r.bench("failover_decide/worker_warm", || {
        wflip = !wflip;
        wctl.on_batch(if wflip { 1.5 } else { 0.5 })
    });

    // --- generation boundary: abort (leader died) vs drain (worker died) --
    let ws = WeightStore::for_model(&model, 5);
    let in_flight = 3usize;
    let ins: Vec<Tensor> =
        (0..in_flight as u64).map(|i| Tensor::random(16, 16, 3, 70 + i)).collect();
    let abort = r.bench("generation/abort_3_in_flight", || {
        let mut p = BlockPipeline::start(&model, &plan, &ws, 4, 4);
        for t in &ins {
            p.submit(t.clone());
        }
        black_box(p.abort())
    });
    let drain = r.bench("generation/drain_3_in_flight", || {
        let mut p = BlockPipeline::start(&model, &plan, &ws, 4, 4);
        for t in &ins {
            p.submit(t.clone());
        }
        black_box(p.finish())
    });

    // --- full audited chaos drill through the pipelined server ------------
    let exp = ChaosExperiment {
        requests: if fast { 12 } else { 32 },
        ..Default::default()
    };
    let schedule = exp.schedule(c4);
    let cfg = ServeConfig {
        max_batch: 1,
        batch_window: Duration::ZERO,
        queue_depth: 64,
        pipeline_depth: exp.pipeline_depth,
        ..ServeConfig::default()
    };
    let t0 = Instant::now();
    let out = run_chaos(
        &model,
        &base,
        &schedule,
        cfg,
        ElasticConfig::default(),
        exp.requests as u64,
        4_242,
    );
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    out.verify().expect("chaos invariants violated in bench");
    println!("chaos drill: {out}");

    emit_result(vec![
        ("leader_failover_decide_us", Json::Num(leader_failover.mean_secs() * 1e6)),
        ("worker_failover_decide_us", Json::Num(worker_failover.mean_secs() * 1e6)),
        ("abort_3_in_flight_ms", Json::Num(abort.mean_secs() * 1e3)),
        ("drain_3_in_flight_ms", Json::Num(drain.mean_secs() * 1e3)),
        ("chaos_requests", Json::Num(out.requests as f64)),
        ("chaos_req_per_s", Json::Num(out.ok as f64 / wall)),
        ("chaos_events", Json::Num(out.events as f64)),
        ("chaos_failovers", Json::Num(out.failovers as f64)),
        ("chaos_leader_handoffs", Json::Num(out.leader_handoffs as f64)),
        ("chaos_speculative_hits", Json::Num(out.speculative_hits as f64)),
        ("chaos_failed_reported", Json::Num(out.failed_reported as f64)),
        ("chaos_lost", Json::Num(out.lost as f64)),
    ]);
}
