//! Wire-transport overhead: what do real sockets cost over in-process
//! channels?
//!
//! Both fabrics run the *identical* lockstep exchange on the identical
//! plan, so the delta is pure transport: frame encode/decode, kernel
//! socket hops and the coordinator round trip. The in-process path is
//! `run_distributed` (threads + channels, the deterministic CI default);
//! the wire path is a registry plus in-thread daemons meshed over
//! TCP-localhost, driven by a [`ProcessCluster`]. Outputs are asserted
//! bit-identical between the two before anything is timed — a transport
//! that changes the numbers has no overhead worth measuring.
//!
//! A second section prices the **replay path**: real daemon OS processes,
//! the leader SIGKILLed mid-run, every request completing through
//! [`ProcessCluster::infer_with_recovery`] — its latency distribution
//! includes the request that rides reinstall-and-replay.
//!
//! The single-line `RESULT` JSON carries both throughputs, the overhead
//! ratio, wire latency percentiles, the leader's per-request wire
//! bytes/messages, and the replay-path percentiles.
//!
//! ```bash
//! cargo bench --bench transport_overhead
//! FLEXPIE_BENCH_FAST=1 cargo bench --bench transport_overhead   # CI smoke
//! ```

use std::io::{BufRead, BufReader};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

use flexpie::cluster::run_distributed;
use flexpie::compute::{Tensor, WeightStore};
use flexpie::config::TransportExperiment;
use flexpie::model::zoo;
use flexpie::partition::{Plan, Scheme};
use flexpie::transport::coord::{InferOutcome, ProcessCluster, RecoveryOutcome};
use flexpie::transport::daemon::{self, DaemonOpts};
use flexpie::transport::registry::RegistryServer;
use flexpie::util::bench::{black_box, emit_result};
use flexpie::util::json::Json;

/// A daemon child process, SIGKILLed (and reaped) on drop.
struct Proc {
    child: Child,
    _out: Option<BufReader<ChildStdout>>,
}

impl Proc {
    fn sigkill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.sigkill();
    }
}

/// Spawn a real `flexpie-node` process and wait for its `READY` banner.
fn spawn_node(node: u32, registry: &str) -> Proc {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_flexpie-node"));
    cmd.args(["--node", &node.to_string(), "--registry", registry]);
    let mut child = cmd.stdout(Stdio::piped()).spawn().expect("spawn flexpie-node");
    let mut out = BufReader::new(child.stdout.take().expect("stdout piped"));
    let mut line = String::new();
    out.read_line(&mut line).expect("read boot banner");
    assert!(line.starts_with("READY "), "unexpected banner: {line:?}");
    Proc { child, _out: Some(out) }
}

fn main() {
    let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
    let exp = TransportExperiment {
        requests: if fast { 8 } else { 48 },
        ..Default::default()
    };
    let model = zoo::by_name(&exp.model).expect("zoo model");
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let ws = WeightStore::for_model(&model, exp.seed);
    let l0 = &model.layers[0];
    let inputs: Vec<Tensor> = (0..exp.requests)
        .map(|i| Tensor::random(l0.in_h, l0.in_w, l0.in_c, 0xBEC + i as u64))
        .collect();

    // --- wire cluster: registry + in-thread daemons over TCP-localhost ---
    let reg = RegistryServer::spawn(&exp.registry, Duration::from_millis(exp.ttl_ms))
        .expect("registry bind");
    for id in 0..exp.nodes as u32 {
        let mut opts = DaemonOpts::new(id, reg.addr());
        opts.tcp = exp.tcp_opts();
        std::thread::spawn(move || {
            let _ = daemon::run(opts);
        });
    }
    let mut pc = ProcessCluster::connect(reg.addr(), exp.nodes, Duration::from_secs(30))
        .expect("cluster bring-up");
    pc.infer_deadline = Duration::from_millis(exp.infer_deadline_ms);
    pc.install(&model, &plan, exp.seed).expect("plan install");

    // correctness gate: both fabrics must agree bit-for-bit before timing
    let wire_probe = match pc.infer(&inputs[0]).expect("probe inference") {
        InferOutcome::Done(run) => run,
        InferOutcome::Failed { dead, .. } => panic!("healthy cluster failed (dead={dead:?})"),
    };
    let local_probe = run_distributed(&model, &plan, &ws, &inputs[0], exp.nodes);
    assert_eq!(
        local_probe.output.max_abs_diff(&wire_probe.output),
        0.0,
        "fabrics disagree — overhead is meaningless"
    );

    // --- in-process baseline ---
    let t0 = Instant::now();
    for input in &inputs {
        black_box(run_distributed(&model, &plan, &ws, input, exp.nodes).output);
    }
    let local_secs = t0.elapsed().as_secs_f64();

    // --- wire run, per-request latencies ---
    let mut lat: Vec<Duration> = Vec::with_capacity(exp.requests);
    let (mut wire_bytes, mut wire_msgs) = (0u64, 0u64);
    let t0 = Instant::now();
    for input in &inputs {
        let t = Instant::now();
        match pc.infer(input).expect("coordinator alive") {
            InferOutcome::Done(run) => {
                lat.push(t.elapsed());
                wire_bytes += run.bytes;
                wire_msgs += run.msgs;
                black_box(run.output);
            }
            InferOutcome::Failed { dead, .. } => panic!("wire run failed (dead={dead:?})"),
        }
    }
    let wire_secs = t0.elapsed().as_secs_f64();
    pc.shutdown();

    // --- replay path: real daemon processes, leader SIGKILLed mid-run ---
    // Every request goes through `infer_with_recovery`, so the one in
    // flight when the leader dies is replayed on the reinstalled survivors
    // instead of failing — its latency prices the whole recovery arc
    // (detection, registry re-resolve, re-election, plan reinstall, replay).
    let replay_requests = if fast { 6 } else { 16 };
    let reg = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_millis(600))
        .expect("registry bind");
    let mut children: Vec<Proc> =
        (0..exp.nodes as u32).map(|id| spawn_node(id, reg.addr())).collect();
    let mut pc = ProcessCluster::connect(reg.addr(), exp.nodes, Duration::from_secs(30))
        .expect("cluster bring-up");
    pc.infer_deadline = Duration::from_secs(10);
    pc.install(&model, &plan, exp.seed).expect("plan install");

    let mut replay_lat: Vec<Duration> = Vec::with_capacity(replay_requests);
    let (mut replays, mut replay_failovers) = (0u64, 0u64);
    let mut killed = false;
    for i in 0..replay_requests {
        let input = &inputs[i % inputs.len()];
        let reference = run_distributed(&model, &plan, &ws, input, exp.nodes).output;
        let t = Instant::now();
        let report = pc.infer_with_recovery(input, 4);
        replays += report.replays as u64;
        replay_failovers += report.failovers as u64;
        match report.outcome {
            RecoveryOutcome::Done(run) => {
                replay_lat.push(t.elapsed());
                assert_eq!(
                    reference.max_abs_diff(&run.output),
                    0.0,
                    "replayed request {i} diverged from the reference"
                );
            }
            RecoveryOutcome::Exhausted => panic!("request {i}: replay budget exhausted"),
            RecoveryOutcome::Dead => panic!("request {i}: cluster declared dead"),
        }
        if !killed {
            children[0].sigkill(); // node 0 — the current leader
            killed = true;
        }
    }
    assert!(replay_failovers >= 1, "leader SIGKILL never forced a reinstall");
    assert!(replays >= 1, "no request rode the replay path");
    pc.shutdown();
    drop(children);
    let rs = flexpie::metrics::summarize(&replay_lat);
    println!(
        "replay path ({replay_requests} reqs, leader SIGKILL mid-run): \
         {replays} replays, {replay_failovers} failovers | latency {rs}"
    );

    let local_rps = exp.requests as f64 / local_secs.max(1e-12);
    let wire_rps = exp.requests as f64 / wire_secs.max(1e-12);
    let overhead = local_secs / wire_secs.max(1e-12); // <1 when wire is slower
    let s = flexpie::metrics::summarize(&lat);
    println!(
        "in-process {local_rps:.1} req/s | wire {wire_rps:.1} req/s \
         (wire/local {:.2}x) | wire latency {s}",
        wire_rps / local_rps.max(1e-12)
    );

    emit_result(vec![
        ("bench", Json::Str("transport_overhead".into())),
        ("experiment", exp.to_json()),
        ("model", Json::Str(model.name.clone())),
        ("requests", Json::Num(exp.requests as f64)),
        ("local_rps", Json::Num(local_rps)),
        ("wire_rps", Json::Num(wire_rps)),
        ("wire_over_local", Json::Num(wire_rps / local_rps.max(1e-12))),
        ("local_over_wire_time", Json::Num(overhead)),
        ("wire_p50_us", Json::Num(s.p50.as_secs_f64() * 1e6)),
        ("wire_p99_us", Json::Num(s.p99.as_secs_f64() * 1e6)),
        ("wire_mean_us", Json::Num(s.mean.as_secs_f64() * 1e6)),
        ("leader_bytes_per_req", Json::Num(wire_bytes as f64 / exp.requests as f64)),
        ("leader_msgs_per_req", Json::Num(wire_msgs as f64 / exp.requests as f64)),
        ("replay_requests", Json::Num(replay_requests as f64)),
        ("replays", Json::Num(replays as f64)),
        ("replay_failovers", Json::Num(replay_failovers as f64)),
        ("replay_p50_us", Json::Num(rs.p50.as_secs_f64() * 1e6)),
        ("replay_p99_us", Json::Num(rs.p99.as_secs_f64() * 1e6)),
        ("bit_identical", Json::Bool(true)),
    ]);
}
