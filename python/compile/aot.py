"""AOT pipeline: lower every artifact-menu layer to HLO **text** and write
`artifacts/manifest.json`.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); Python never runs at inference
time.

Usage: python -m compile.aot --out-dir ../artifacts [--check]
"""

import argparse
import json
import pathlib
import sys
import time

import jax

from .model import LayerSpec, artifact_menu, example_args, layer_fn


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the version-safe path)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: LayerSpec) -> str:
    fn = layer_fn(spec, use_pallas=True)
    lowered = jax.jit(fn).lower(*example_args(spec))
    return to_hlo_text(lowered)


def self_check(spec: LayerSpec) -> float:
    """Numerically check the pallas lowering against the pure-jnp reference
    (returns max abs diff)."""
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.RandomState(hash(spec.signature()) % (2**31))
    args = [
        jnp.asarray(rng.randn(*a.shape).astype("float32") * 0.1)
        for a in example_args(spec)
    ]
    (got,) = layer_fn(spec, use_pallas=True)(*args)
    (want,) = layer_fn(spec, use_pallas=False)(*args)
    return float(jnp.abs(got - want).max())


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--check", action="store_true",
                    help="numerically check each kernel vs the reference")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest: dict[str, str] = {}
    t0 = time.time()
    for spec in artifact_menu():
        sig = spec.signature()
        fname = f"{sig}.hlo.txt"
        text = lower_spec(spec)
        (out_dir / fname).write_text(text)
        manifest[sig] = fname
        extra = ""
        if args.check:
            diff = self_check(spec)
            extra = f"  maxdiff={diff:.2e}"
            assert diff < 1e-4, f"{sig}: pallas vs ref diff {diff}"
        print(f"  {sig:<44} -> {fname} ({len(text)} chars){extra}")

    (out_dir / "manifest.json").write_text(
        json.dumps(
            {
                "artifacts": manifest,
                "generated_by": "python/compile/aot.py",
                "jax_version": jax.__version__,
                "format": "hlo-text (xla_extension 0.5.1 compatible)",
            },
            indent=2,
            sort_keys=True,
        )
    )
    print(f"wrote {len(manifest)} artifacts + manifest.json to {out_dir} "
          f"in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
