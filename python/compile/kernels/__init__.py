"""Layer-1 Pallas kernels (build-time only; never on the request path)."""

from .conv2d import conv2d  # noqa: F401
from .dwconv import dwconv  # noqa: F401
from .matmul import dense_hwc, matmul  # noqa: F401
