"""Layer-1 Pallas kernel: depthwise convolution (MobileNet's dominant op).

Same VMEM staging pattern as `conv2d.py`, but the per-tap inner op is an
elementwise multiply-accumulate over the channel lane dimension (the VPU,
not the MXU — depthwise convs are memory-bound, which is exactly why the
analytic device model gives them a low efficiency factor).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, stride: int, block_rows: int):
    row0 = pl.program_id(0) * block_rows
    x = x_ref[...]
    w = w_ref[...]
    _, ow, c = o_ref.shape
    acc = jnp.zeros((block_rows, ow, c), jnp.float32) + b_ref[...]
    for ky in range(k):
        for kx in range(k):
            patch = jax.lax.dynamic_slice(
                x,
                (row0 * stride + ky, kx, 0),
                ((block_rows - 1) * stride + 1, (ow - 1) * stride + 1, c),
            )
            acc = acc + patch[::stride, ::stride, :] * w[ky, kx]
    o_ref[...] = acc


def dwconv(x, w, b, *, stride: int = 1, pad: int = 0, relu: bool = False,
           block_rows: int | None = None, interpret: bool = True):
    """Pallas depthwise conv. x: (h, w, c); w: (k, k, c); b: (c,)."""
    k = int(w.shape[0])
    c = int(x.shape[2])
    oh = (x.shape[0] + 2 * pad - k) // stride + 1
    ow = (x.shape[1] + 2 * pad - k) // stride + 1
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))

    if block_rows is None:
        block_rows = oh
        for cand in range(oh, 0, -1):
            if oh % cand == 0 and cand * ow * c <= 2 * 1024 * 1024 // 4:
                block_rows = cand
                break
    assert oh % block_rows == 0

    kernel = functools.partial(_dw_kernel, k=k, stride=stride, block_rows=block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(oh // block_rows,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, ow, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.float32),
        interpret=interpret,
    )(xp, w, b)
    return jnp.maximum(out, 0.0) if relu else out
