"""Layer-1 Pallas kernel: tiled direct convolution.

The paper's compute hot-spot is the per-device convolution over a
partitioned feature-map tile. The DSP implementation stages L2-SRAM stripes
of the input; the TPU adaptation (DESIGN.md §Hardware-Adaptation) maps that
staging onto VMEM tiles:

* grid over **output-row blocks** — each grid step owns `block_rows` output
  rows; the pipeline double-buffers the next stripe while the MXU works;
* the inner computation is expressed as K·K **per-tap matmuls**
  `(rows·W, InC) @ (InC, OutC)` so the MXU systolic array (not a scalar MAC
  loop) does the accumulation;
* the halo (the paper's boundary data, §2.3) is materialized by passing the
  *padded* input resident and slicing `block_rows·s + k − 1` rows per step —
  the in-VMEM equivalent of the T-mode boundary transfer.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU numbers are estimated from the BlockSpec footprint in
DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, stride: int, block_rows: int):
    """One grid step: compute `block_rows` output rows."""
    row0 = pl.program_id(0) * block_rows
    x = x_ref[...]  # padded input, resident (small edge tiles fit VMEM)
    w = w_ref[...]
    oh, ow, oc = o_ref.shape
    acc = jnp.zeros((block_rows, ow, oc), jnp.float32) + b_ref[...]
    for ky in range(k):
        for kx in range(k):
            # rows row0*s+ky .. step s; cols kx .. step s — a (block_rows, ow,
            # ic) patch, contracted against the (ic, oc) tap on the MXU.
            patch = jax.lax.dynamic_slice(
                x,
                (row0 * stride + ky, kx, 0),
                ((block_rows - 1) * stride + 1, (ow - 1) * stride + 1, x.shape[2]),
            )
            patch = patch[::stride, ::stride, :]
            acc = acc + jax.lax.dot_general(
                patch,
                w[ky, kx],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = acc


def conv2d(x, w, b, *, stride: int = 1, pad: int = 0, relu: bool = False,
           block_rows: int | None = None, interpret: bool = True):
    """Pallas direct conv. x: (h, w, c); w: (k, k, ic, oc); b: (oc,)."""
    k = int(w.shape[0])
    oc = int(w.shape[3])
    oh = (x.shape[0] + 2 * pad - k) // stride + 1
    ow = (x.shape[1] + 2 * pad - k) // stride + 1
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))

    if block_rows is None:
        # pick the largest divisor of oh that keeps the out stripe ≲ 2 MiB
        block_rows = oh
        budget = 2 * 1024 * 1024 // 4
        for cand in range(oh, 0, -1):
            if oh % cand == 0 and cand * ow * oc <= budget:
                block_rows = cand
                break
    assert oh % block_rows == 0, (oh, block_rows)

    kernel = functools.partial(_conv_kernel, k=k, stride=stride, block_rows=block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(oh // block_rows,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),  # padded input resident
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, ow, oc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, oc), jnp.float32),
        interpret=interpret,
    )(xp, w, b)
    return jnp.maximum(out, 0.0) if relu else out


def vmem_estimate_bytes(h: int, w: int, c_in: int, c_out: int, k: int, stride: int,
                        pad: int, block_rows: int) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf):
    resident padded input + weights + bias + one output stripe + accumulator."""
    hp, wp = h + 2 * pad, w + 2 * pad
    ow = (w + 2 * pad - k) // stride + 1
    return 4 * (
        hp * wp * c_in  # input stripe (resident here; stripes on real TPU)
        + k * k * c_in * c_out  # weights
        + c_out  # bias
        + 2 * block_rows * ow * c_out  # out stripe + accumulator
    )
