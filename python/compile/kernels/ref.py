"""Pure-jnp reference oracles for the Layer-1 Pallas kernels.

Hand-rolled per-tap accumulation (no ``lax.conv``) so the reference is a
transparent, independently-checkable statement of the semantics the Rust
native kernels (`rust/src/compute/`) and the Pallas kernels must both match.

Layout conventions (shared across all three layers of the stack):
  feature maps  — HWC, f32
  conv weights  — (k, k, in_c, out_c)
  dwconv weights— (k, k, c)
  dense weights — (in_c, out_c)
  bias          — (out_c,)
"""

import jax.numpy as jnp


def conv2d_ref(x, w, b, stride: int, pad: int):
    """Standard convolution; zero padding, square kernel/stride."""
    k = w.shape[0]
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (x.shape[0] + 2 * pad - k) // stride + 1
    ow = (x.shape[1] + 2 * pad - k) // stride + 1
    out = jnp.broadcast_to(b, (oh, ow, w.shape[3])).astype(jnp.float32)
    for ky in range(k):
        for kx in range(k):
            patch = xp[
                ky : ky + (oh - 1) * stride + 1 : stride,
                kx : kx + (ow - 1) * stride + 1 : stride,
                :,
            ]
            out = out + jnp.einsum(
                "hwi,io->hwo", patch, w[ky, kx], preferred_element_type=jnp.float32
            )
    return out


def dwconv_ref(x, w, b, stride: int, pad: int):
    """Depthwise convolution: one k×k filter per channel."""
    k = w.shape[0]
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (x.shape[0] + 2 * pad - k) // stride + 1
    ow = (x.shape[1] + 2 * pad - k) // stride + 1
    out = jnp.broadcast_to(b, (oh, ow, x.shape[2])).astype(jnp.float32)
    for ky in range(k):
        for kx in range(k):
            patch = xp[
                ky : ky + (oh - 1) * stride + 1 : stride,
                kx : kx + (ow - 1) * stride + 1 : stride,
                :,
            ]
            out = out + patch * w[ky, kx]
    return out


def dense_ref(x, w, b):
    """Row-wise matmul: (rows, in_c) @ (in_c, out_c) + b.

    ``x`` may be (rows, 1, in_c) (the HWC embedding used by the Rust IR) or
    (rows, in_c).
    """
    squeeze = x.ndim == 3
    if squeeze:
        x = x[:, 0, :]
    out = x @ w + b
    return out[:, None, :] if squeeze else out


def avgpool_ref(x, k: int, stride: int):
    """Average pooling, no padding (matches the Rust kernel: divide by k²)."""
    oh = (x.shape[0] - k) // stride + 1
    ow = (x.shape[1] - k) // stride + 1
    out = jnp.zeros((oh, ow, x.shape[2]), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            out = out + x[
                ky : ky + (oh - 1) * stride + 1 : stride,
                kx : kx + (ow - 1) * stride + 1 : stride,
                :,
            ]
    return out / float(k * k)


def relu(x):
    return jnp.maximum(x, 0.0)
