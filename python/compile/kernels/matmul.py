"""Layer-1 Pallas kernel: blocked matmul (FC / BERT layers).

Classic MXU-shaped tiling: grid over (M-blocks × N-blocks); each step
contracts a (bm, K) × (K, bn) pair with an f32 accumulator. Block sizes
default to 128 (the MXU lane width) clamped to the problem size.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = (
        jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...]
    )


def matmul(x, w, b, *, relu: bool = False, bm: int | None = None,
           bn: int | None = None, interpret: bool = True):
    """(M, K) @ (K, N) + b. Grid-blocked over M and N."""
    m, k = int(x.shape[0]), int(x.shape[1])
    n = int(w.shape[1])

    def pick(dim, pref):
        for cand in (pref, 64, 32, 16, 8, 4, 2, 1):
            if cand <= dim and dim % cand == 0:
                return cand
        return 1

    bm = bm or pick(m, 128)
    bn = bn or pick(n, 128)
    assert m % bm == 0 and n % bn == 0

    out = pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)
    return jnp.maximum(out, 0.0) if relu else out


def dense_hwc(x, w, b, *, relu: bool = False, interpret: bool = True):
    """HWC-embedded dense layer: (rows, 1, in_c) → (rows, 1, out_c)."""
    rows = x.shape[0]
    out = matmul(x.reshape(rows, x.shape[2]), w, b, relu=relu, interpret=interpret)
    return out.reshape(rows, 1, w.shape[1])


def mxu_utilization(m: int, k: int, n: int, bm: int = 128, bn: int = 128) -> float:
    """Fraction of MXU work that is useful (edge-tile padding waste), for
    DESIGN.md §Perf: util = (m·k·n) / (ceil(m/bm)·bm · k · ceil(n/bn)·bn)."""
    import math

    mp = math.ceil(m / bm) * bm
    np_ = math.ceil(n / bn) * bn
    return (m * k * n) / (mp * k * np_)
