"""Layer-2: the JAX layer ops and model graphs that call the Layer-1 Pallas
kernels. Build-time only — `aot.py` lowers these functions to HLO text once;
the Rust runtime executes the artifacts at inference time.

The shape menu mirrors `rust/src/model/zoo.rs::edgenet` exactly; the two
sides meet at `artifacts/manifest.json` via the shared signature scheme
(`rust/src/runtime/mod.rs::signature`).
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels import conv2d, dense_hwc, dwconv
from .kernels import ref


@dataclass(frozen=True)
class LayerSpec:
    """Mirror of the Rust `LayerMeta` fields that matter for lowering."""

    name: str
    op: str  # conv2d | dwconv | dense | avgpool
    in_h: int
    in_w: int
    in_c: int
    out_c: int
    k: int
    s: int
    p: int
    relu: bool = False

    @property
    def out_h(self) -> int:
        if self.op == "dense":
            return self.in_h
        return (self.in_h + 2 * self.p - self.k) // self.s + 1

    @property
    def out_w(self) -> int:
        if self.op == "dense":
            return 1
        return (self.in_w + 2 * self.p - self.k) // self.s + 1

    def signature(self) -> str:
        """Must match rust/src/runtime/mod.rs::signature."""
        relu = "_relu" if self.relu else ""
        if self.op == "dense":
            return f"dense_m{self.in_h}_k{self.in_c}_n{self.out_c}{relu}"
        return (
            f"{self.op}_ih{self.in_h}_iw{self.in_w}_ic{self.in_c}"
            f"_oc{self.out_c}_k{self.k}_s{self.s}_p{self.p}{relu}"
        )


def layer_fn(spec: LayerSpec, use_pallas: bool = True):
    """The jax function for one layer, returning a 1-tuple (the AOT recipe
    lowers with return_tuple=True and the Rust side unwraps to_tuple1)."""
    if spec.op == "conv2d":
        def fn(x, w, b):
            if use_pallas:
                out = conv2d(x, w, b, stride=spec.s, pad=spec.p, relu=spec.relu)
            else:
                out = ref.conv2d_ref(x, w, b, spec.s, spec.p)
                if spec.relu:
                    out = ref.relu(out)
            return (out,)
        return fn
    if spec.op == "dwconv":
        def fn(x, w, b):
            if use_pallas:
                out = dwconv(x, w, b, stride=spec.s, pad=spec.p, relu=spec.relu)
            else:
                out = ref.dwconv_ref(x, w, b, spec.s, spec.p)
                if spec.relu:
                    out = ref.relu(out)
            return (out,)
        return fn
    if spec.op == "dense":
        def fn(x, w, b):
            if use_pallas:
                out = dense_hwc(x, w, b, relu=spec.relu)
            else:
                out = ref.dense_ref(x, w, b)
                if spec.relu:
                    out = ref.relu(out)
            return (out,)
        return fn
    if spec.op == "avgpool":
        def fn(x):
            return (ref.avgpool_ref(x, spec.k, spec.s),)
        return fn
    raise ValueError(f"unknown op {spec.op}")


def example_args(spec: LayerSpec):
    """ShapeDtypeStructs for lowering."""
    import jax

    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((spec.in_h, spec.in_w, spec.in_c), f32)
    if spec.op == "avgpool":
        return (x,)
    if spec.op == "dense":
        w = jax.ShapeDtypeStruct((spec.in_c, spec.out_c), f32)
    elif spec.op == "dwconv":
        w = jax.ShapeDtypeStruct((spec.k, spec.k, spec.out_c), f32)
    else:
        w = jax.ShapeDtypeStruct((spec.k, spec.k, spec.in_c, spec.out_c), f32)
    b = jax.ShapeDtypeStruct((spec.out_c,), f32)
    return (x, w, b)


def edgenet_specs(input_size: int = 16) -> list[LayerSpec]:
    """Mirror of rust zoo::edgenet(input) — the quickstart/AOT model."""
    assert input_size % 8 == 0
    h1, h2 = input_size // 2, input_size // 4
    return [
        LayerSpec("c0", "conv2d", input_size, input_size, 3, 8, 3, 1, 1),
        LayerSpec("dw1", "dwconv", input_size, input_size, 8, 8, 3, 2, 1),
        LayerSpec("pw1", "conv2d", h1, h1, 8, 16, 1, 1, 0),
        LayerSpec("c2", "conv2d", h1, h1, 16, 16, 3, 1, 1),
        LayerSpec("dw2", "dwconv", h1, h1, 16, 16, 3, 2, 1),
        LayerSpec("pw2", "conv2d", h2, h2, 16, 32, 1, 1, 0),
        LayerSpec("c3", "conv2d", h2, h2, 32, 32, 3, 1, 1),
        LayerSpec("avgpool", "avgpool", h2, h2, 32, 32, h2, h2, 0),
        LayerSpec("fc", "dense", 1, 1, 32, 10, 1, 1, 0),
    ]


def artifact_menu() -> list[LayerSpec]:
    """Every (op, shape) lowered by aot.py: the EdgeNet quickstart model at
    input sizes 16/32/64 (the Rust e2e_runtime test uses 16; the e2e_serving
    example uses 64, where distribution genuinely pays off)."""
    menu: list[LayerSpec] = []
    seen: set[str] = set()
    for size in (16, 32, 64):
        for spec in edgenet_specs(size):
            sig = spec.signature()
            if sig not in seen:
                seen.add(sig)
                menu.append(spec)
    return menu


def run_chain(specs: list[LayerSpec], x, params, use_pallas: bool = True):
    """Run a whole chain (used by tests to check L2 composition)."""
    for spec in specs:
        fn = layer_fn(spec, use_pallas=use_pallas)
        if spec.op == "avgpool":
            (x,) = fn(x)
        else:
            w, b = params[spec.name]
            (x,) = fn(x, w, b)
    return x
