"""AOT pipeline tests: HLO-text lowering and manifest structure."""

import json
import pathlib
import subprocess
import sys

from compile.aot import lower_spec, self_check
from compile.model import edgenet_specs


def test_lower_spec_produces_hlo_text():
    spec = edgenet_specs(16)[0]
    text = lower_spec(spec)
    assert "HloModule" in text
    # pallas interpret-mode lowers to plain HLO ops, no mosaic custom-calls
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
    # entry computation returns a tuple (return_tuple=True)
    assert "ROOT" in text


def test_self_check_all_edgenet16_layers():
    for spec in edgenet_specs(16):
        diff = self_check(spec)
        assert diff < 1e-4, f"{spec.signature()}: {diff}"


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        cwd=pathlib.Path(__file__).resolve().parents[1],
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert "artifacts" in manifest
    for sig, fname in manifest["artifacts"].items():
        path = out / fname
        assert path.exists(), sig
        assert "HloModule" in path.read_text()[:200]
