"""Layer-2 tests: layer specs, signatures, and full-chain composition."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.model import (
    LayerSpec,
    artifact_menu,
    edgenet_specs,
    example_args,
    layer_fn,
    run_chain,
)


def make_params(specs, seed=0):
    rng = np.random.RandomState(seed)
    params = {}
    for spec in specs:
        if spec.op == "avgpool":
            continue
        args = example_args(spec)
        w = jnp.asarray(rng.randn(*args[1].shape).astype(np.float32) * 0.2)
        b = jnp.asarray(rng.randn(*args[2].shape).astype(np.float32) * 0.1)
        params[spec.name] = (w, b)
    return params


def test_edgenet_specs_chain_shapes():
    specs = edgenet_specs(16)
    assert len(specs) == 9
    # consecutive shape compatibility
    for a, b in zip(specs, specs[1:]):
        assert (a.out_h, a.out_w if a.op != "dense" else 1, a.out_c)[2] == b.in_c
        assert a.out_h == b.in_h
    assert specs[-1].out_c == 10


def test_signatures_match_rust_scheme():
    specs = edgenet_specs(16)
    assert specs[0].signature() == "conv2d_ih16_iw16_ic3_oc8_k3_s1_p1"
    assert specs[1].signature() == "dwconv_ih16_iw16_ic8_oc8_k3_s2_p1"
    assert specs[-1].signature() == "dense_m1_k32_n10"
    assert specs[-2].signature() == "avgpool_ih4_iw4_ic32_oc32_k4_s4_p0"


def test_artifact_menu_unique_and_covers_edgenet16():
    menu = artifact_menu()
    sigs = [s.signature() for s in menu]
    assert len(sigs) == len(set(sigs))
    for spec in edgenet_specs(16):
        assert spec.signature() in sigs


def test_chain_pallas_matches_ref():
    specs = edgenet_specs(16)
    params = make_params(specs)
    x = jnp.asarray(np.random.RandomState(7).randn(16, 16, 3).astype(np.float32))
    got = run_chain(specs, x, params, use_pallas=True)
    want = run_chain(specs, x, params, use_pallas=False)
    assert got.shape == (1, 1, 10)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_layer_fns_jittable():
    for spec in edgenet_specs(16)[:3]:
        fn = jax.jit(layer_fn(spec))
        args = [
            jnp.zeros(a.shape, a.dtype) for a in example_args(spec)
        ]
        (out,) = fn(*args)
        assert out.shape == (spec.out_h, spec.out_w, spec.out_c)


def test_out_shape_arithmetic():
    s = LayerSpec("t", "conv2d", 224, 224, 3, 32, 3, 2, 1)
    assert (s.out_h, s.out_w) == (112, 112)
    d = LayerSpec("fc", "dense", 1, 1, 32, 10, 1, 1, 0)
    assert (d.out_h, d.out_w, d.out_c) == (1, 1, 10)
