"""Layer-1 correctness: Pallas kernels vs the pure-jnp reference oracle.

Hypothesis sweeps shapes / kernel geometry; assert_allclose against ref.py.
This is the CORE correctness signal for the compute hot-spot (the Rust
native kernels are checked against the same semantics on their side, and
the e2e_runtime Rust test closes the loop via the AOT artifacts).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import conv2d, dwconv, matmul, dense_hwc
from compile.kernels import ref
from compile.kernels.conv2d import vmem_estimate_bytes
from compile.kernels.matmul import mxu_utilization

RNG = np.random.RandomState(1234)


def rand(*shape, scale=0.5):
    return jnp.asarray(RNG.randn(*shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

conv_cases = st.tuples(
    st.sampled_from([4, 6, 8, 12, 16]),          # h (= w)
    st.sampled_from([1, 2, 3, 8]),               # in_c
    st.sampled_from([1, 4, 8]),                  # out_c
    st.sampled_from([(1, 0), (3, 1), (5, 2), (3, 0)]),  # (k, p)
    st.sampled_from([1, 2]),                     # stride
)


@settings(max_examples=60, deadline=None)
@given(conv_cases, st.booleans())
def test_conv2d_matches_ref(case, relu):
    h, ic, oc, (k, p), s = case
    if h + 2 * p < k:
        return
    x = rand(h, h, ic)
    w = rand(k, k, ic, oc, scale=0.2)
    b = rand(oc, scale=0.1)
    got = conv2d(x, w, b, stride=s, pad=p, relu=relu)
    want = ref.conv2d_ref(x, w, b, s, p)
    if relu:
        want = ref.relu(want)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_rows", [1, 2, 4, 8])
def test_conv2d_block_rows_invariant(block_rows):
    """Tiling must not change results."""
    x = rand(8, 8, 3)
    w = rand(3, 3, 3, 4, scale=0.2)
    b = rand(4, scale=0.1)
    base = ref.conv2d_ref(x, w, b, 1, 1)
    got = conv2d(x, w, b, stride=1, pad=1, block_rows=block_rows)
    assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-5, atol=1e-5)


def test_conv2d_identity_kernel():
    x = rand(6, 6, 2)
    w = jnp.zeros((1, 1, 2, 2), jnp.float32)
    w = w.at[0, 0, 0, 0].set(1.0).at[0, 0, 1, 1].set(1.0)
    b = jnp.zeros(2, jnp.float32)
    got = conv2d(x, w, b)
    assert_allclose(np.asarray(got), np.asarray(x), rtol=0, atol=0)


def test_conv2d_vmem_estimate_positive_and_monotone():
    small = vmem_estimate_bytes(16, 16, 8, 16, 3, 1, 1, 4)
    large = vmem_estimate_bytes(64, 64, 8, 16, 3, 1, 1, 4)
    assert 0 < small < large


# ---------------------------------------------------------------------------
# dwconv
# ---------------------------------------------------------------------------

dw_cases = st.tuples(
    st.sampled_from([4, 8, 14, 16]),
    st.sampled_from([1, 3, 8, 16]),
    st.sampled_from([(3, 1), (3, 0), (5, 2)]),
    st.sampled_from([1, 2]),
)


@settings(max_examples=40, deadline=None)
@given(dw_cases)
def test_dwconv_matches_ref(case):
    h, c, (k, p), s = case
    if h + 2 * p < k:
        return
    x = rand(h, h, c)
    w = rand(k, k, c, scale=0.3)
    b = rand(c, scale=0.1)
    got = dwconv(x, w, b, stride=s, pad=p)
    want = ref.dwconv_ref(x, w, b, s, p)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dwconv_channel_independence():
    x = rand(6, 6, 3)
    w = rand(3, 3, 3, scale=0.3)
    b = jnp.zeros(3, jnp.float32)
    base = dwconv(x, w, b, stride=1, pad=1)
    x2 = x.at[:, :, 2].add(1.0)
    got = dwconv(x2, w, b, stride=1, pad=1)
    assert_allclose(np.asarray(got[:, :, :2]), np.asarray(base[:, :, :2]), rtol=0, atol=0)
    assert np.abs(np.asarray(got[:, :, 2] - base[:, :, 2])).max() > 0


# ---------------------------------------------------------------------------
# matmul / dense
# ---------------------------------------------------------------------------

mm_cases = st.tuples(
    st.sampled_from([1, 2, 8, 33, 128]),  # m
    st.sampled_from([4, 32, 96]),         # k
    st.sampled_from([2, 10, 64, 130]),    # n
)


@settings(max_examples=40, deadline=None)
@given(mm_cases, st.booleans())
def test_matmul_matches_ref(case, relu):
    m, k, n = case
    x = rand(m, k)
    w = rand(k, n, scale=0.2)
    b = rand(n, scale=0.1)
    got = matmul(x, w, b, relu=relu)
    want = x @ w + b
    if relu:
        want = ref.relu(want)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_dense_hwc_embedding():
    x = rand(4, 1, 8)
    w = rand(8, 3, scale=0.2)
    b = rand(3, scale=0.1)
    got = dense_hwc(x, w, b)
    want = ref.dense_ref(x, w, b)
    assert got.shape == (4, 1, 3)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mxu_utilization_bounds():
    assert mxu_utilization(128, 128, 128) == 1.0
    u = mxu_utilization(7, 512, 10)
    assert 0 < u < 0.01  # tiny FC tiles waste the MXU — recorded in §Perf


# ---------------------------------------------------------------------------
# avgpool ref sanity (executed by the Rust engine's pool layers)
# ---------------------------------------------------------------------------

def test_avgpool_global():
    x = jnp.arange(4 * 4 * 2, dtype=jnp.float32).reshape(4, 4, 2)
    out = ref.avgpool_ref(x, 4, 4)
    assert out.shape == (1, 1, 2)
    assert_allclose(np.asarray(out[0, 0]), np.asarray(x.reshape(16, 2).mean(0)), rtol=1e-6)
