"""Kernel-level partition correctness: the Layer-1 story of the paper.

A node computing an InH tile of a conv layer receives its input rows plus
the receptive-field halo (T mode) — running the kernel on that slice must
produce exactly the corresponding slice of the full-layer output. The same
invariant the Rust engine verifies end-to-end, checked here at the kernel
boundary, including NT-mode two-layer fusion (inflated tiles).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import conv2d
from compile.kernels import ref


def split_even(length, n, i):
    base, rem = divmod(length, n)
    start = i * base + min(i, rem)
    return start, start + base + (1 if i < rem else 0)


def rows_with_halo_zero_padded(x, r0, r1, k, p):
    """The T-mode input a node holds for output rows [r0, r1): its input
    rows plus halo, with feature-map-boundary rows materialized as zeros
    (what conv padding would have produced)."""
    h = x.shape[0]
    lo, hi = r0 - p, (r1 - 1) + k - p  # unclamped receptive rows
    top_zeros = max(0, -lo)
    bot_zeros = max(0, hi - h)
    tile = x[max(lo, 0) : min(hi, h)]
    return jnp.pad(tile, ((top_zeros, bot_zeros), (0, 0), (0, 0)))


cases = st.tuples(
    st.sampled_from([12, 16, 24]),  # h
    st.sampled_from([2, 3, 4]),     # nodes
    st.sampled_from([1, 3, 8]),     # channels
)


@settings(max_examples=25, deadline=None)
@given(cases)
def test_inh_tile_with_halo_matches_full_conv(case):
    h, nodes, c = case
    k, p, s = 3, 1, 1
    rng = np.random.RandomState(h * nodes + c)
    x = jnp.asarray(rng.randn(h, h, c).astype(np.float32))
    w = jnp.asarray(rng.randn(k, k, c, 4).astype(np.float32) * 0.2)
    b = jnp.asarray(rng.randn(4).astype(np.float32) * 0.1)
    full = ref.conv2d_ref(x, w, b, s, p)

    pieces = []
    for node in range(nodes):
        r0, r1 = split_even(h, nodes, node)
        tile_in = rows_with_halo_zero_padded(x, r0, r1, k, p)
        # rows: valid conv over the zero-padded halo tile reproduces the
        # padded semantics; width: keep the kernel's own padding
        out = conv2d(
            jnp.pad(tile_in, ((0, 0), (p, p), (0, 0))),
            w,
            b,
            stride=s,
            pad=0,
            interpret=True,
        )
        assert out.shape == (r1 - r0, h, 4)
        pieces.append(out)
    assembled = jnp.concatenate(pieces, axis=0)
    assert_allclose(np.asarray(assembled), np.asarray(full), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([12, 16]), st.sampled_from([2, 4]))
def test_nt_fused_two_layer_tile(h, nodes):
    """NT mode: each node computes an *inflated* first-layer tile so the
    second layer needs no exchange; the assembled outputs equal the chained
    full convolutions exactly."""
    c = 3
    k, p, s = 3, 1, 1
    halo = (k - 1) // 2
    rng = np.random.RandomState(h + nodes)
    x = jnp.asarray(rng.randn(h, h, c).astype(np.float32))
    w1 = jnp.asarray(rng.randn(k, k, c, c).astype(np.float32) * 0.2)
    w2 = jnp.asarray(rng.randn(k, k, c, c).astype(np.float32) * 0.2)
    b = jnp.zeros(c, jnp.float32)
    full = ref.conv2d_ref(ref.conv2d_ref(x, w1, b, s, p), w2, b, s, p)

    pieces = []
    for node in range(nodes):
        r0, r1 = split_even(h, nodes, node)
        # inflated layer-1 rows (clamp handled by zero-materialization)
        i0, i1 = r0 - halo, r1 + halo
        # entry input for the inflated tile (scattered once; NT inside)
        entry = rows_with_halo_zero_padded(x, max(i0, 0), min(i1, h), k, p)
        mid = conv2d(
            jnp.pad(entry, ((0, 0), (p, p), (0, 0))),
            w1,
            b,
            stride=s,
            pad=0,
            interpret=True,
        )  # rows max(i0,0)..min(i1,h) of layer-1 output, full width
        # materialize the boundary zeros of the inflated tile
        mid = jnp.pad(mid, ((max(0, -i0), max(0, i1 - h)), (0, 0), (0, 0)))
        # local layer-2 (no exchange): valid rows, padded width
        out = conv2d(
            jnp.pad(mid, ((0, 0), (p, p), (0, 0))),
            w2,
            b,
            stride=s,
            pad=0,
            interpret=True,
        )
        assert out.shape == (r1 - r0, h, c)
        pieces.append(out)
    assembled = jnp.concatenate(pieces, axis=0)
    assert_allclose(np.asarray(assembled), np.asarray(full), rtol=1e-4, atol=1e-4)
